//! The long-lived compile server.
//!
//! A [`CompileServer`] owns one [`ScheduleCache`] for its whole lifetime,
//! hydrated from the persistent artifact ([`crate::scheduler::persist`])
//! at construction and re-persisted (atomic temp-file + rename) whenever
//! a request executed new schedule sweeps. Every compile request —
//! whether it arrives in-process or over the Unix socket front door
//! ([`super::socket`]) — gets fresh per-request compilers wired to that
//! shared cache, so:
//!
//! * repeated layer shapes across requests, models and processes are
//!   searched **once**;
//! * the per-layer schedule stage is pre-sharded across a bounded worker
//!   pool (`workers` threads walk the distinct `(shape, target)` pairs of
//!   the request), so a cold model's searches run in parallel before the
//!   deterministic session emits code from an all-hit cache;
//! * concurrent requests sharing a shape never duplicate work: the
//!   cache's single-flight gate blocks followers until the leader
//!   publishes (see [`ScheduleCache::begin`]).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::accel::AccelDesc;
use crate::backend::Backend;
use crate::baselines::naive_byoc::import_with_weight_chain;
use crate::frontend::{configure_all, run_frontend_passes};
use crate::isa::program::Program;
use crate::obs::prom::{Counter, Gauge, Histogram, Registry, LATENCY_BUCKETS};
use crate::pipeline::{
    CompileOptions, Compiler, Deployment, MultiCompiler, MultiDeployment, ScheduleStats,
    SessionMemo, StageReport,
};
use crate::relay::import::QModel;
use crate::relay::Graph;
use crate::scheduler::cache::{
    accel_fingerprint, CacheKey, CacheStats, ScheduleCache, SearchKey,
};
use crate::scheduler::persist::{self, LoadReport};
use crate::workload::Gemm;

/// What a compile request produced: single- and multi-target deployments
/// keep their native types (a single-target program stays byte-identical
/// to the plain [`Compiler`] path).
#[derive(Debug, Clone)]
#[allow(clippy::large_enum_variant)]
pub enum CompiledArtifact {
    /// One accelerator target.
    Single(Deployment),
    /// Several candidate targets (cost-driven partition).
    Multi(MultiDeployment),
}

impl CompiledArtifact {
    /// The emitted program, whichever deployment shape was produced.
    pub fn program(&self) -> &Program {
        match self {
            CompiledArtifact::Single(d) => &d.program,
            CompiledArtifact::Multi(d) => &d.program,
        }
    }

    /// Number of accelerator layers in the deployment.
    pub fn layers(&self) -> usize {
        match self {
            CompiledArtifact::Single(d) => d.chosen.len(),
            CompiledArtifact::Multi(d) => d.assignments.len(),
        }
    }

    /// A stable content hash of the emitted program (disassembly bytes),
    /// for byte-identity assertions across processes.
    pub fn program_fnv(&self) -> u64 {
        persist::fnv1a64(self.program().disassemble().as_bytes())
    }
}

/// One request's result: the artifact plus the observability the service
/// promises (per-stage timing, schedule counters, this request's cache
/// hit/miss deltas and sweep count).
#[derive(Debug, Clone)]
pub struct ServiceReply {
    /// The compiled deployment.
    pub artifact: CompiledArtifact,
    /// Per-stage timing + diagnostics from the session.
    pub stages: Vec<StageReport>,
    /// Schedule-selection counters from the session's schedule stage.
    pub schedule_stats: ScheduleStats,
    /// Cache hits attributable to this request (prewarm + session).
    pub cache_hits: u64,
    /// Cache misses attributable to this request.
    pub cache_misses: u64,
    /// Schedule sweeps this request actually executed (0 = fully warm).
    pub sweeps: u64,
    /// Solver leaves costed by this request's sweeps (prewarm + session;
    /// 0 = fully warm). The search effort behind `sweeps`.
    pub solver_leaves_visited: u64,
    /// Dominated sweep configuration points that rode a shared group
    /// search instead of running their own DFS (see
    /// [`crate::scheduler::solver::SearchStats`]).
    pub configs_pruned: u64,
    /// Wall-clock time of the whole request.
    pub elapsed: Duration,
}

/// The long-lived compile server. See the module docs.
pub struct CompileServer {
    cache: Arc<ScheduleCache>,
    cache_path: Option<PathBuf>,
    /// Incremental-session memo served to the `*_incremental` requests;
    /// persisted as a `.memo` sibling of the cache artifact.
    memo: SessionMemo,
    memo_path: Option<PathBuf>,
    options: CompileOptions,
    workers: usize,
    persist_lock: Mutex<()>,
    requests: AtomicU64,
    metrics: ServerMetrics,
}

/// The server's Prometheus instrumentation: one registry per server,
/// bumped on the serve path and rendered by
/// [`CompileServer::metrics_text`] (exposed over the socket's `metrics`
/// verb and `tvm-accel metrics --socket`). Strictly passive — nothing
/// here feeds back into compilation.
struct ServerMetrics {
    registry: Registry,
    requests_total: Arc<Counter>,
    in_flight: Arc<Gauge>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    sweeps: Arc<Counter>,
    solver_leaves: Arc<Counter>,
    configs_pruned: Arc<Counter>,
    prewarm_queue_depth: Arc<Gauge>,
    cache_entries: Arc<Gauge>,
    compile_duration: Arc<Histogram>,
}

impl ServerMetrics {
    fn new() -> ServerMetrics {
        let registry = Registry::new();
        let requests_total =
            registry.counter("tvmaccel_requests_total", "Compile requests accepted.");
        let in_flight = registry
            .gauge("tvmaccel_requests_in_flight", "Compile requests currently executing.");
        let cache_hits = registry.counter(
            "tvmaccel_cache_hits_total",
            "Schedule-cache hits attributed to compile requests.",
        );
        let cache_misses = registry.counter(
            "tvmaccel_cache_misses_total",
            "Schedule-cache misses attributed to compile requests.",
        );
        let sweeps = registry
            .counter("tvmaccel_schedule_sweeps_total", "Schedule sweeps executed by requests.");
        let solver_leaves = registry.counter(
            "tvmaccel_solver_leaves_total",
            "Solver leaves costed by request sweeps.",
        );
        let configs_pruned = registry.counter(
            "tvmaccel_configs_pruned_total",
            "Dominated sweep configuration points skipped by request sweeps.",
        );
        let prewarm_queue_depth = registry.gauge(
            "tvmaccel_prewarm_queue_depth",
            "Schedule searches queued on the prewarm worker pool.",
        );
        let cache_entries =
            registry.gauge("tvmaccel_cache_entries", "Entries in the shared schedule cache.");
        let compile_duration = registry.histogram(
            "tvmaccel_compile_duration_seconds",
            "Wall-clock latency of whole compile requests.",
            LATENCY_BUCKETS,
        );
        ServerMetrics {
            registry,
            requests_total,
            in_flight,
            cache_hits,
            cache_misses,
            sweeps,
            solver_leaves,
            configs_pruned,
            prewarm_queue_depth,
            cache_entries,
            compile_duration,
        }
    }

    /// The per-stage latency series for `stage` (registered on first use).
    fn stage_duration(&self, stage: &str) -> Arc<Histogram> {
        self.registry.histogram_with(
            "tvmaccel_stage_duration_seconds",
            "Per-stage compile latency.",
            LATENCY_BUCKETS,
            &[("stage", stage)],
        )
    }
}

/// Drop guard pairing the in-flight gauge increment with its decrement on
/// every exit path (including compile errors).
struct InFlight<'a>(&'a Gauge);

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        self.0.add(-1);
    }
}

/// The session-memo artifact's location: a `.memo` sibling of the
/// schedule-cache artifact (`schedules.bin` → `schedules.bin.memo`).
pub fn memo_sibling_path(cache: &Path) -> PathBuf {
    let mut os = cache.as_os_str().to_os_string();
    os.push(".memo");
    PathBuf::from(os)
}

impl CompileServer {
    /// A server with a fresh in-memory cache and no persistence.
    pub fn new(options: CompileOptions) -> CompileServer {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        CompileServer {
            cache: Arc::new(ScheduleCache::new()),
            cache_path: None,
            memo: SessionMemo::new(),
            memo_path: None,
            options,
            workers,
            persist_lock: Mutex::new(()),
            requests: AtomicU64::new(0),
            metrics: ServerMetrics::new(),
        }
    }

    /// A server whose cache is hydrated from (and persisted back to) the
    /// artifact at `path`, and whose incremental-session memo is hydrated
    /// from the `.memo` sibling ([`memo_sibling_path`]). A missing or
    /// unreadable artifact starts cold — never an error. Returns the
    /// server plus what the cache load found.
    pub fn with_cache_file(
        options: CompileOptions,
        path: PathBuf,
    ) -> (CompileServer, LoadReport) {
        let mut server = CompileServer::new(options);
        let report = persist::hydrate_from_file(&server.cache, &path);
        let memo_path = memo_sibling_path(&path);
        persist::hydrate_memo_from_file(&server.memo, &memo_path);
        server.cache_path = Some(path);
        server.memo_path = Some(memo_path);
        (server, report)
    }

    /// Bound the schedule-search worker pool to `n` threads per request
    /// (minimum 1; default: available parallelism).
    pub fn with_workers(mut self, n: usize) -> CompileServer {
        self.workers = n.max(1);
        self
    }

    /// The shared schedule cache.
    pub fn cache(&self) -> Arc<ScheduleCache> {
        self.cache.clone()
    }

    /// Lifetime cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Where the cache persists, when persistence is enabled.
    pub fn cache_path(&self) -> Option<&Path> {
        self.cache_path.as_deref()
    }

    /// The incremental-session memo backing the `*_incremental` requests.
    pub fn memo(&self) -> &SessionMemo {
        &self.memo
    }

    /// Where the memo persists (the `.memo` sibling), when persistence is
    /// enabled.
    pub fn memo_path(&self) -> Option<&Path> {
        self.memo_path.as_deref()
    }

    /// Compile requests served so far.
    pub fn requests_served(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// The server's metrics in Prometheus text exposition format:
    /// request/cache/sweep counters, worker-pool queue depth, and
    /// per-stage latency histograms. The cache-entry gauge is refreshed
    /// at scrape time so it reflects the shared cache's current size.
    pub fn metrics_text(&self) -> String {
        self.metrics.cache_entries.set(self.cache.stats().entries as i64);
        self.metrics.registry.render()
    }

    /// Drop every cached selection, in memory and on disk.
    pub fn clear_cache(&self) -> Result<()> {
        self.cache.clear();
        if let Some(path) = &self.cache_path {
            match std::fs::remove_file(path) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => {
                    return Err(e).with_context(|| format!("removing {}", path.display()))
                }
            }
        }
        Ok(())
    }

    /// Atomically write the current cache contents to the artifact file
    /// (and the incremental-session memo to its `.memo` sibling, when it
    /// has entries). No-op (returning 0) without a configured path.
    pub fn persist(&self) -> Result<usize> {
        let Some(path) = &self.cache_path else { return Ok(0) };
        let _guard = self.persist_lock.lock().expect("persist lock poisoned");
        if let Some(memo_path) = &self.memo_path {
            if !self.memo.is_empty() {
                persist::save_memo_to_file(&self.memo, memo_path)?;
            }
        }
        persist::save_to_file(&self.cache, path)
    }

    /// Compile a `.qmodel` (imported exactly like the CLI's `proposed`
    /// backend) for `targets`.
    pub fn compile_model(
        &self,
        model: &QModel,
        targets: &[AccelDesc],
    ) -> Result<ServiceReply> {
        let graph = import_with_weight_chain(model)?;
        self.compile_graph(&graph, targets)
    }

    /// [`CompileServer::compile_model`] through the server's
    /// incremental-session memo ([`CompileServer::compile_graph_incremental`]).
    pub fn compile_model_incremental(
        &self,
        model: &QModel,
        targets: &[AccelDesc],
    ) -> Result<ServiceReply> {
        let graph = import_with_weight_chain(model)?;
        self.compile_graph_incremental(&graph, targets)
    }

    /// Compile an in-memory graph for one or many targets. One target
    /// produces [`CompiledArtifact::Single`] (byte-identical to the plain
    /// [`Compiler`] path); several produce the cost-partitioned
    /// [`CompiledArtifact::Multi`].
    pub fn compile_graph(
        &self,
        graph: &Graph,
        targets: &[AccelDesc],
    ) -> Result<ServiceReply> {
        self.compile_graph_with(graph, targets, None)
    }

    /// [`CompileServer::compile_graph`] through the server's long-lived
    /// incremental-session memo: layers the memo already knows skip even
    /// the shared-cache gate, newly searched selections are recorded, and
    /// memo growth triggers a persist of the `.memo` sibling — so a later
    /// *process* resumes where this one stopped.
    pub fn compile_graph_incremental(
        &self,
        graph: &Graph,
        targets: &[AccelDesc],
    ) -> Result<ServiceReply> {
        self.compile_graph_with(graph, targets, Some(&self.memo))
    }

    fn compile_graph_with(
        &self,
        graph: &Graph,
        targets: &[AccelDesc],
        memo: Option<&SessionMemo>,
    ) -> Result<ServiceReply> {
        ensure!(!targets.is_empty(), "compile request needs at least one target");
        let t0 = Instant::now();
        let memo_len0 = memo.map(|m| m.len()).unwrap_or(0);
        self.metrics.requests_total.inc();
        self.metrics.in_flight.add(1);
        let _in_flight = InFlight(&self.metrics.in_flight);

        // Per-request compilers over the server's long-lived cache.
        let warmers: Vec<Arc<Compiler>> = targets
            .iter()
            .map(|a| {
                Arc::new(Compiler::with_shared_cache(
                    a.clone(),
                    self.options.clone(),
                    self.cache.clone(),
                ))
            })
            .collect();

        // Shard the schedule searches before the (deterministic, in-order)
        // session runs: afterwards every session lookup is a cache hit.
        self.prewarm(graph, &warmers, memo)?;

        // Per-request attribution comes from the request's own compilers
        // (the warmers; plus the MultiCompiler's candidates in the
        // multi-target case) — the shared cache's global counters would
        // pick up concurrent requests' traffic.
        let (artifact, stages, schedule_stats, session) = if targets.len() == 1 {
            let out = match memo {
                Some(m) => warmers[0].compile_incremental_with_report(graph, m)?,
                None => warmers[0].compile_with_report(graph)?,
            };
            (
                CompiledArtifact::Single(out.deployment),
                out.stages,
                out.schedule_stats,
                // The warmer is the session compiler; counted below.
                (0, 0, 0, 0, 0),
            )
        } else {
            let mc = MultiCompiler::with_shared_cache(
                targets.to_vec(),
                self.options.clone(),
                self.cache.clone(),
            )?;
            let out = match memo {
                Some(m) => mc.compile_incremental_with_report(graph, m)?,
                None => mc.compile_with_report(graph)?,
            };
            (
                CompiledArtifact::Multi(out.deployment),
                out.stages,
                out.schedule_stats,
                (
                    mc.sweeps_run(),
                    mc.cache_hits(),
                    mc.cache_misses(),
                    mc.solver_leaves_visited(),
                    mc.configs_pruned(),
                ),
            )
        };
        let sweeps: u64 = warmers.iter().map(|c| c.sweeps_run()).sum::<u64>() + session.0;
        let cache_hits: u64 =
            warmers.iter().map(|c| c.cache_hits()).sum::<u64>() + session.1;
        let cache_misses: u64 =
            warmers.iter().map(|c| c.cache_misses()).sum::<u64>() + session.2;
        let solver_leaves_visited: u64 =
            warmers.iter().map(|c| c.solver_leaves_visited()).sum::<u64>() + session.3;
        let configs_pruned: u64 =
            warmers.iter().map(|c| c.configs_pruned()).sum::<u64>() + session.4;

        // Write-on-update: only requests that learned something new —
        // fresh sweeps, or fresh memo entries — pay the (atomic) persist.
        let memo_grew = memo.map(|m| m.len() > memo_len0).unwrap_or(false);
        if sweeps > 0 || memo_grew {
            self.persist()?;
        }
        self.requests.fetch_add(1, Ordering::Relaxed);

        // Metrics last, off the same numbers the reply reports.
        self.metrics.cache_hits.add(cache_hits);
        self.metrics.cache_misses.add(cache_misses);
        self.metrics.sweeps.add(sweeps);
        self.metrics.solver_leaves.add(solver_leaves_visited);
        self.metrics.configs_pruned.add(configs_pruned);
        self.metrics.compile_duration.observe(t0.elapsed().as_secs_f64());
        for s in &stages {
            self.metrics.stage_duration(s.name).observe(s.elapsed.as_secs_f64());
        }

        Ok(ServiceReply {
            artifact,
            stages,
            schedule_stats,
            cache_hits,
            cache_misses,
            sweeps,
            solver_leaves_visited,
            configs_pruned,
            elapsed: t0.elapsed(),
        })
    }

    /// Run the request's schedule searches on the bounded worker pool: one
    /// job per distinct `(accelerator fingerprint, GEMM shape)` pair of
    /// the frontend-processed graph. Failed probes (shape infeasible on a
    /// candidate) are skipped here — the session reports them with full
    /// per-layer context.
    fn prewarm(
        &self,
        graph: &Graph,
        warmers: &[Arc<Compiler>],
        memo: Option<&SessionMemo>,
    ) -> Result<()> {
        let accels: Vec<&AccelDesc> = warmers.iter().map(|c| &c.accel).collect();
        let mut fcfg = configure_all(&accels);
        fcfg.fold_constants = self.options.fold_constants;
        let processed = run_frontend_passes(graph, &fcfg)?;

        let mut seen: std::collections::BTreeSet<(u64, Gemm)> =
            std::collections::BTreeSet::new();
        let mut jobs: Vec<(Arc<Compiler>, u64, Gemm)> = Vec::new();
        for c in warmers {
            let fp = accel_fingerprint(&c.accel);
            let backend = c.backend()?;
            let supported = c.accel.supported_ops();
            for n in &processed.nodes {
                if !supported.contains(n.op.name()) {
                    continue;
                }
                let shapes: Vec<Vec<usize>> = n
                    .inputs
                    .iter()
                    .map(|&i| processed.node(i).ty.shape.clone())
                    .collect();
                let Ok(strategy) = backend.generate_strategy(&c.accel, n, &shapes) else {
                    continue; // unbindable here; the session will explain
                };
                // Counter-neutral peek: already-warm shapes (the steady
                // state of a long-lived server) spawn no search work. Only
                // the unconstrained selections are prewarmed; the
                // session's cross-layer stage runs (and memoizes) any
                // boundary-constrained re-searches it needs.
                let key = CacheKey::unconstrained(
                    fp,
                    strategy.gemm,
                    SearchKey::new(&self.options.sweep, self.options.profile_candidates),
                );
                if self.cache.contains(&key) {
                    continue;
                }
                if memo.is_some_and(|m| m.contains(&key)) {
                    continue; // the session serves this straight from the memo
                }
                if seen.insert((fp, strategy.gemm)) {
                    jobs.push((c.clone(), fp, strategy.gemm));
                }
            }
        }

        self.metrics.prewarm_queue_depth.add(jobs.len() as i64);
        if jobs.len() <= 1 {
            for (c, fp, g) in &jobs {
                let _ = c.select_schedule(*g, *fp, memo);
                self.metrics.prewarm_queue_depth.add(-1);
            }
            return Ok(());
        }
        let next = AtomicUsize::new(0);
        let workers = self.workers.min(jobs.len());
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let (c, fp, g) = &jobs[i];
                    // Single-flight inside: concurrent requests sharing
                    // this key wait here instead of re-searching.
                    let _ = c.select_schedule(*g, *fp, memo);
                    self.metrics.prewarm_queue_depth.add(-1);
                });
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::relay::import::{synth_qmodel, to_qnn_graph};

    fn mlp_graph(seed: u64, dims: &[usize], batch: usize) -> Graph {
        to_qnn_graph(&synth_qmodel(seed, dims, batch).unwrap()).unwrap()
    }

    #[test]
    fn second_request_is_fully_warm_and_byte_identical() {
        let server = CompileServer::new(CompileOptions::default());
        let graph = mlp_graph(41, &[32, 48, 16], 4);
        let accel = gemmini_desc().unwrap();

        let cold = server.compile_graph(&graph, std::slice::from_ref(&accel)).unwrap();
        assert!(cold.sweeps >= 2, "at least one sweep per distinct shape");
        assert_eq!(cold.artifact.layers(), 2);
        assert!(cold.cache_misses > 0);
        assert!(cold.solver_leaves_visited > 0, "cold sweeps cost solver leaves");

        let warm = server.compile_graph(&graph, std::slice::from_ref(&accel)).unwrap();
        assert_eq!(warm.sweeps, 0, "second identical request must be all hits");
        assert_eq!(warm.cache_misses, 0);
        assert_eq!(warm.solver_leaves_visited, 0, "warm requests spend no search effort");
        assert!(warm.cache_hits >= 2);
        assert_eq!(
            warm.artifact.program().items,
            cold.artifact.program().items,
            "warm compile must emit byte-identical code"
        );
        assert_eq!(warm.artifact.program_fnv(), cold.artifact.program_fnv());
        assert_eq!(server.requests_served(), 2);
    }

    #[test]
    fn server_matches_plain_compiler_output() {
        let server = CompileServer::new(CompileOptions::default());
        let graph = mlp_graph(42, &[24, 24, 24], 2);
        let accel = gemmini_desc().unwrap();
        let reply = server.compile_graph(&graph, std::slice::from_ref(&accel)).unwrap();
        let plain = Compiler::new(accel).compile(&graph).unwrap();
        let CompiledArtifact::Single(dep) = &reply.artifact else {
            panic!("single target must yield a single deployment");
        };
        assert_eq!(dep.program.items, plain.program.items);
        assert_eq!(
            reply.stages.iter().map(|s| s.name).collect::<Vec<_>>(),
            ["frontend", "partition", "schedule", "crosslayer", "mapping", "codegen", "link"]
        );
        // Prewarm ran every search up front: the session saw only hits.
        assert_eq!(reply.schedule_stats.searched, 0);
        assert_eq!(reply.schedule_stats.cache_hits, reply.schedule_stats.layers);
    }

    #[test]
    fn metrics_text_reflects_request_traffic() {
        let server = CompileServer::new(CompileOptions::default());
        let graph = mlp_graph(44, &[16, 16], 2);
        let accel = gemmini_desc().unwrap();
        server.compile_graph(&graph, std::slice::from_ref(&accel)).unwrap();
        server.compile_graph(&graph, std::slice::from_ref(&accel)).unwrap();
        let text = server.metrics_text();
        assert!(text.contains("tvmaccel_requests_total 2"), "text was:\n{text}");
        assert!(text.contains("tvmaccel_requests_in_flight 0"));
        assert!(text.contains("tvmaccel_prewarm_queue_depth 0"));
        assert!(text.contains("# TYPE tvmaccel_compile_duration_seconds histogram"));
        assert!(text.contains("tvmaccel_compile_duration_seconds_count 2"));
        assert!(
            text.contains("tvmaccel_stage_duration_seconds_bucket{stage=\"schedule\""),
            "per-stage series registered from stage reports"
        );
        let field = |name: &str| -> i64 {
            text.lines()
                .find(|l| l.starts_with(name) && l.split_whitespace().count() == 2)
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(|| panic!("no {name} sample in:\n{text}"))
        };
        assert!(field("tvmaccel_cache_hits_total") >= 1, "warm request must record hits");
        assert!(field("tvmaccel_schedule_sweeps_total") >= 1, "cold request swept");
        assert!(field("tvmaccel_cache_entries") >= 1, "gauge refreshed at scrape time");
    }

    #[test]
    fn concurrent_identical_requests_run_each_sweep_once() {
        let server = Arc::new(CompileServer::new(CompileOptions::default()));
        let graph = mlp_graph(43, &[40, 16, 16, 8], 1);
        let accel = gemmini_desc().unwrap();
        let replies: Vec<ServiceReply> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let server = server.clone();
                    let graph = graph.clone();
                    let accel = accel.clone();
                    scope.spawn(move || {
                        server
                            .compile_graph(&graph, std::slice::from_ref(&accel))
                            .expect("compile")
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("request panicked")).collect()
        });
        // 3 distinct shapes (plus any boundary-constrained re-searches);
        // the single-flight gate must make the *sum* of sweeps across both
        // concurrent requests exactly the distinct-search count — which a
        // third, fully warm request pins down as final.
        let total: u64 = replies.iter().map(|r| r.sweeps).sum();
        assert!(total >= 3, "each distinct shape swept at least once");
        assert_eq!(
            replies[0].artifact.program().items,
            replies[1].artifact.program().items
        );
        let third = server
            .compile_graph(&graph, std::slice::from_ref(&accel))
            .expect("third request");
        assert_eq!(third.sweeps, 0, "everything was searched exactly once before");
        assert_eq!(third.artifact.program().items, replies[0].artifact.program().items);
    }
}
