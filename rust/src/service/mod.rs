//! The compile service: a long-lived, sharded compile server over a
//! persistent schedule cache.
//!
//! The paper hides schedule search behind a high-level entry point, but a
//! one-shot CLI pays that search on every invocation — only a long-lived
//! in-memory cache amortizes it. This module turns the staged
//! [`crate::pipeline::CompilerSession`] machinery into a serving-grade
//! path (the ROADMAP's "sharded compile service" item, mirroring how TVM
//! amortizes tuning logs across compilations):
//!
//! * [`server::CompileServer`] — a long-lived object owning one
//!   [`crate::scheduler::cache::ScheduleCache`] hydrated from the on-disk
//!   artifact ([`crate::scheduler::persist`]). Each compile request gets
//!   per-request compilers wired to that shared cache; the per-layer
//!   schedule stage is pre-sharded across a bounded worker pool, and the
//!   cache's single-flight gate guarantees concurrent requests never
//!   duplicate an in-flight search. Responses carry the deployment plus
//!   per-stage timing and cache hit/miss counters; the artifact is
//!   re-persisted (atomically) whenever a request ran new sweeps.
//! * [`protocol`] — the newline-delimited JSON-ish wire format (no
//!   external dependencies: a minimal flat-object parser/serializer).
//! * [`socket`] — the Unix-domain-socket front door behind
//!   `tvm-accel serve`, plus the one-shot client used by
//!   `tvm-accel compile --socket`.
//!
//! ```text
//!   tvm-accel compile --socket /run/tvm-accel.sock   (client, warm)
//!        │  {"cmd":"compile","model":"m.qmodel"}\n
//!        ▼
//!   UnixListener ── connection thread ──▶ CompileServer
//!                                          │  hydrate ⇄ persist (atomic)
//!                                          ▼
//!                                 ScheduleCache (single-flight)
//!                                          ▲
//!                 worker pool: schedule-search shards per (shape, target)
//! ```

#![warn(missing_docs)]

pub mod protocol;
pub mod server;
pub mod socket;

use std::path::PathBuf;

pub use server::{memo_sibling_path, CompileServer, CompiledArtifact, ServiceReply};

/// Default location of the persistent schedule-cache artifact:
/// `$TVM_ACCEL_CACHE` when set, else `$XDG_CACHE_HOME/tvm-accel/` (or
/// `$HOME/.cache/tvm-accel/`, or `./.tvm-accel/` as a last resort)
/// `schedules.bin`.
pub fn default_cache_path() -> PathBuf {
    if let Some(p) = std::env::var_os("TVM_ACCEL_CACHE") {
        return PathBuf::from(p);
    }
    let base = std::env::var_os("XDG_CACHE_HOME")
        .map(PathBuf::from)
        .or_else(|| std::env::var_os("HOME").map(|h| PathBuf::from(h).join(".cache")));
    match base {
        Some(b) => b.join("tvm-accel").join("schedules.bin"),
        None => PathBuf::from(".tvm-accel").join("schedules.bin"),
    }
}
