//! Unix-domain-socket front door for the compile service.
//!
//! [`serve`] binds a socket, accepts connections on a thread apiece, and
//! answers the newline-delimited [`super::protocol`] messages against a
//! shared [`CompileServer`]. `{"cmd":"shutdown"}` persists the cache and
//! stops the accept loop; [`request`] is the one-shot client used by
//! `tvm-accel compile --socket` (and by anything else that wants a warm
//! compile without linking the crate).

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::accel::AccelDesc;
use crate::arch::parse::{arch_from_yaml, backend_from_yaml};
use crate::relay::import::load_qmodel;

use super::protocol::{parse_message, Message, ObjBuilder};
use super::server::{CompileServer, CompiledArtifact};

/// Configuration of one serving loop.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Path of the Unix domain socket (an existing file is replaced).
    pub socket: PathBuf,
    /// Targets used when a request names no `arch` files.
    pub default_targets: Vec<AccelDesc>,
}

/// Serve requests until a `shutdown` message arrives. Blocks the calling
/// thread; connections are handled concurrently (one thread each), all
/// sharing `server`'s cache. On exit the cache is persisted and the
/// socket file removed.
pub fn serve(server: Arc<CompileServer>, opts: ServeOptions) -> Result<()> {
    let _ = std::fs::remove_file(&opts.socket);
    let listener = UnixListener::bind(&opts.socket)
        .with_context(|| format!("binding socket {}", opts.socket.display()))?;
    let stop = Arc::new(AtomicBool::new(false));
    let targets = Arc::new(opts.default_targets);
    let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        // Reap finished connection threads so a long-lived server's
        // handle list doesn't grow with every one-shot client.
        workers.retain(|w| !w.is_finished());
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                // Transient failure (EMFILE under a client burst, EINTR):
                // back off and keep serving instead of dying from a
                // recoverable load spike.
                eprintln!("tvm-accel serve: accept error (retrying): {e}");
                std::thread::sleep(std::time::Duration::from_millis(100));
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // Bound how long an idle connection can hold its thread (and
        // therefore delay shutdown); a request in flight is unaffected.
        let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(120)));
        let server = server.clone();
        let stop = stop.clone();
        let targets = targets.clone();
        let socket_path = opts.socket.clone();
        workers.push(std::thread::spawn(move || {
            handle_connection(&server, stream, &targets, &stop, &socket_path);
        }));
    }
    for w in workers {
        let _ = w.join();
    }
    server.persist()?;
    let _ = std::fs::remove_file(&opts.socket);
    Ok(())
}

/// Read request lines off one connection until EOF (or shutdown).
fn handle_connection(
    server: &CompileServer,
    stream: UnixStream,
    default_targets: &[AccelDesc],
    stop: &AtomicBool,
    socket_path: &Path,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let reader = BufReader::new(read_half);
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let (reply, shutdown) = handle_line(server, &line, default_targets);
        if writeln!(writer, "{reply}").and_then(|_| writer.flush()).is_err() {
            break;
        }
        if shutdown {
            stop.store(true, Ordering::SeqCst);
            // Unblock the accept loop so it observes the stop flag.
            let _ = UnixStream::connect(socket_path);
            break;
        }
    }
}

/// Dispatch one request line; returns the response line plus whether the
/// server should shut down.
fn handle_line(
    server: &CompileServer,
    line: &str,
    default_targets: &[AccelDesc],
) -> (String, bool) {
    let msg = match parse_message(line) {
        Ok(m) => m,
        Err(e) => return (error_reply("parse", &format!("{e:#}")), false),
    };
    let cmd = msg.cmd().to_string();
    match cmd.as_str() {
        "ping" => (ok_reply(server, &cmd).finish(), false),
        "stats" => {
            let mut b = ok_reply(server, &cmd);
            if let Some(p) = server.cache_path() {
                b = b.str_field("cache_file", &p.display().to_string());
            }
            (b.num_field("requests", server.requests_served()).finish(), false)
        }
        "clear" => match server.clear_cache() {
            Ok(()) => (ok_reply(server, &cmd).finish(), false),
            Err(e) => (error_reply(&cmd, &format!("{e:#}")), false),
        },
        // The Prometheus scrape: the exposition text travels as one
        // escaped string field (the protocol escapes newlines), so any
        // line-oriented client can unwrap it.
        "metrics" => (
            ObjBuilder::new()
                .bool_field("ok", true)
                .str_field("cmd", &cmd)
                .str_field("exposition", &server.metrics_text())
                .finish(),
            false,
        ),
        "shutdown" => match server.persist() {
            Ok(persisted) => (
                ok_reply(server, &cmd).num_field("persisted", persisted as u64).finish(),
                true,
            ),
            Err(e) => (error_reply(&cmd, &format!("{e:#}")), true),
        },
        "compile" => match handle_compile(server, &msg, default_targets) {
            Ok(reply) => (reply, false),
            Err(e) => (error_reply(&cmd, &format!("{e:#}")), false),
        },
        other => (error_reply(other, "unknown command"), false),
    }
}

fn handle_compile(
    server: &CompileServer,
    msg: &Message,
    default_targets: &[AccelDesc],
) -> Result<String> {
    let model_path =
        msg.str_field("model").context("compile request needs a \"model\" path")?;
    let model = load_qmodel(Path::new(model_path))?;
    let arch_files = msg.str_list("arch");
    let targets: Vec<AccelDesc> = if arch_files.is_empty() {
        default_targets.to_vec()
    } else {
        let mut out = Vec::with_capacity(arch_files.len());
        for f in &arch_files {
            out.push(load_target(Path::new(f))?);
        }
        out
    };
    let reply = server.compile_model(&model, &targets)?;
    let stage_summary: Vec<String> = reply
        .stages
        .iter()
        .map(|s| format!("{}:{}us", s.name, s.elapsed.as_micros()))
        .collect();
    let stats = server.cache_stats();
    let mut b = ObjBuilder::new()
        .bool_field("ok", true)
        .str_field("cmd", "compile")
        .num_field("items", reply.artifact.program().items.len() as u64)
        .num_field("dram_bytes", reply.artifact.program().layout.total_bytes())
        .num_field("layers", reply.artifact.layers() as u64)
        .num_field("cache_hits", reply.cache_hits)
        .num_field("cache_misses", reply.cache_misses)
        .num_field("sweeps", reply.sweeps)
        .num_field("solver_leaves_visited", reply.solver_leaves_visited)
        .num_field("configs_pruned", reply.configs_pruned)
        .num_field("memo_hits", reply.schedule_stats.memo_hits as u64)
        .num_field("resident_edges", reply.schedule_stats.resident_edges as u64)
        .num_field("cache_entries", stats.entries as u64)
        .num_field("elapsed_us", reply.elapsed.as_micros() as u64)
        .str_field("program_fnv", &format!("{:016x}", reply.artifact.program_fnv()));
    // Multi-target compiles carry the async timing model's estimate:
    // the serial per-layer sum against the boundary-overlapped makespan.
    if let CompiledArtifact::Multi(d) = &reply.artifact {
        let (serial, overlapped) = d.overlap_estimate();
        b = b
            .num_field("serial_cycles_est", serial)
            .num_field("overlapped_cycles_est", overlapped);
    }
    Ok(b.list_field("stages", &stage_summary).finish())
}

/// Load one accelerator description from an accelerator config YAML: the
/// architectural half plus the `backend:` registry id (default gemmini),
/// dispatched through the backend registry. An unknown backend id is a
/// clean configuration error naming the known backends.
pub fn load_target(path: &Path) -> Result<AccelDesc> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let arch =
        arch_from_yaml(&src).with_context(|| format!("parsing {}", path.display()))?;
    let backend_id = backend_from_yaml(&src)
        .with_context(|| format!("parsing {}", path.display()))?;
    let backend = crate::backend::lookup(&backend_id)
        .with_context(|| format!("resolving backend of {}", path.display()))?;
    let name = arch.name.clone();
    backend.make_desc(&name, arch)
}

fn ok_reply(server: &CompileServer, cmd: &str) -> ObjBuilder {
    let stats = server.cache_stats();
    ObjBuilder::new()
        .bool_field("ok", true)
        .str_field("cmd", cmd)
        .num_field("cache_entries", stats.entries as u64)
        .num_field("cache_hits", stats.hits)
        .num_field("cache_misses", stats.misses)
}

fn error_reply(cmd: &str, error: &str) -> String {
    ObjBuilder::new()
        .bool_field("ok", false)
        .str_field("cmd", cmd)
        .str_field("error", error)
        .finish()
}

/// One-shot client: connect to a serving socket, send one request line,
/// return the (trimmed) response line.
pub fn request(socket: &Path, line: &str) -> Result<String> {
    let mut stream = UnixStream::connect(socket).with_context(|| {
        format!("connecting to compile server at {}", socket.display())
    })?;
    // Bound the wait: a server draining toward shutdown may never accept
    // this backlog entry, and a hung server should fail the client loudly
    // rather than block it forever. 10 minutes covers a cold compile.
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(600)));
    let _ = stream.set_write_timeout(Some(std::time::Duration::from_secs(60)));
    writeln!(stream, "{line}").context("sending request")?;
    stream.flush().context("flushing request")?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).context("reading response")?;
    anyhow::ensure!(!resp.is_empty(), "server closed the connection without replying");
    Ok(resp.trim_end().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::backend::vector::vector_desc;
    use crate::pipeline::CompileOptions;
    use crate::relay::import::{write_qmodel, QModel};
    use crate::relay::quantize::{quantize_mlp, FloatDense};
    use crate::util::prng::Rng;

    fn tiny_model() -> QModel {
        let mut rng = Rng::new(9);
        let l = FloatDense {
            weight: (0..16 * 8).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
            bias: (0..8).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: 16,
            out_dim: 8,
            relu: false,
        };
        crate::relay::import::from_quantized(
            1,
            0.04,
            &quantize_mlp(&[l], &[0.04, 0.05]).unwrap(),
        )
    }

    #[test]
    fn multi_target_compile_reply_carries_overlap_estimate() {
        let dir = std::env::temp_dir()
            .join(format!("tvm-accel-socket-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let model_path = dir.join("tiny.qmodel");
        std::fs::write(&model_path, write_qmodel(&tiny_model())).unwrap();
        let server = CompileServer::new(CompileOptions::default());
        let targets = vec![gemmini_desc().unwrap(), vector_desc().unwrap()];
        let line = format!("{{\"cmd\":\"compile\",\"model\":\"{}\"}}", model_path.display());
        let (reply, shutdown) = handle_line(&server, &line, &targets);
        assert!(!shutdown);
        let msg = parse_message(&reply).unwrap();
        assert_eq!(msg.bool_field("ok"), Some(true), "reply: {reply}");
        let serial = msg.num_field("serial_cycles_est").expect("serial estimate");
        let overlapped =
            msg.num_field("overlapped_cycles_est").expect("overlapped estimate");
        assert!(serial > 0.0, "reply: {reply}");
        assert!(overlapped > 0.0 && overlapped <= serial, "reply: {reply}");
        // Single-target compiles stay free of the multi-only fields.
        let single = vec![gemmini_desc().unwrap()];
        let (reply, _) = handle_line(&server, &line, &single);
        let msg = parse_message(&reply).unwrap();
        assert_eq!(msg.bool_field("ok"), Some(true), "reply: {reply}");
        assert_eq!(msg.num_field("serial_cycles_est"), None, "reply: {reply}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
