//! Newline-delimited JSON-ish wire protocol for the compile service.
//!
//! One request per line, one response per line. Both sides are *flat*
//! JSON objects (no nesting — a deliberate subset so the hand-rolled
//! parser stays tiny and dependency-free): string, number, boolean and
//! array-of-string values only.
//!
//! Requests:
//!
//! ```text
//! {"cmd":"ping"}
//! {"cmd":"compile","model":"/abs/path/m.qmodel","arch":["configs/gemmini.yaml"]}
//! {"cmd":"stats"}
//! {"cmd":"clear"}
//! {"cmd":"shutdown"}
//! ```
//!
//! `arch` is optional (the server's default targets apply) and may name
//! several YAML files for a multi-accelerator compile. Responses always
//! carry `"ok":true|false`; compile responses add `items`, `dram_bytes`,
//! `layers`, `cache_hits`/`cache_misses`/`sweeps` (this request's deltas),
//! `solver_leaves_visited`/`configs_pruned` (the search effort behind
//! those sweeps — zero on a fully warm request), `cache_entries`,
//! `elapsed_us` and `program_fnv` (a stable content hash of the emitted
//! program, hex-encoded so no precision is lost in JSON numbers).

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

/// A decoded value (the protocol's deliberately small JSON subset).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A string.
    Str(String),
    /// A number (integers and floats collapse to `f64`).
    Num(f64),
    /// `true` / `false`.
    Bool(bool),
    /// `null`.
    Null,
    /// An array (of any subset value; the protocol uses string arrays).
    Arr(Vec<Value>),
}

/// One parsed message: a flat JSON object.
#[derive(Debug, Clone, Default)]
pub struct Message {
    fields: BTreeMap<String, Value>,
}

impl Message {
    /// The `cmd` field ("" when absent).
    pub fn cmd(&self) -> &str {
        self.str_field("cmd").unwrap_or("")
    }

    /// A string field, when present and a string.
    pub fn str_field(&self, name: &str) -> Option<&str> {
        match self.fields.get(name) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// A numeric field, when present and a number.
    pub fn num_field(&self, name: &str) -> Option<f64> {
        match self.fields.get(name) {
            Some(Value::Num(n)) => Some(*n),
            _ => None,
        }
    }

    /// A boolean field, when present and a boolean.
    pub fn bool_field(&self, name: &str) -> Option<bool> {
        match self.fields.get(name) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// A field that is either one string or an array of strings, as a
    /// list (empty when absent or of another type).
    pub fn str_list(&self, name: &str) -> Vec<String> {
        match self.fields.get(name) {
            Some(Value::Str(s)) => vec![s.clone()],
            Some(Value::Arr(a)) => a
                .iter()
                .filter_map(|v| match v {
                    Value::Str(s) => Some(s.clone()),
                    _ => None,
                })
                .collect(),
            _ => Vec::new(),
        }
    }
}

// --- parsing ----------------------------------------------------------

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.s.len() && self.s[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.s.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.peek() {
            Some(got) if got == b => {
                self.pos += 1;
                Ok(())
            }
            got => bail!("expected '{}' at byte {}, found {:?}", b as char, self.pos, got),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            ensure!(self.pos < self.s.len(), "unterminated string");
            let b = self.s[self.pos];
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    ensure!(self.pos < self.s.len(), "dangling escape");
                    let e = self.s[self.pos];
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        other => bail!("unsupported escape '\\{}'", other as char),
                    }
                }
                _ => {
                    // Pass UTF-8 continuation bytes through unchanged.
                    out.push(b as char);
                    if b >= 0x80 {
                        // Rebuild multi-byte characters from raw bytes.
                        out.pop();
                        let start = self.pos - 1;
                        let mut end = self.pos;
                        while end < self.s.len() && self.s[end] >= 0x80 && self.s[end] < 0xc0 {
                            end += 1;
                        }
                        match std::str::from_utf8(&self.s[start..end]) {
                            Ok(chunk) => out.push_str(chunk),
                            Err(_) => bail!("invalid UTF-8 in string"),
                        }
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<f64> {
        self.skip_ws();
        let start = self.pos;
        while self.pos < self.s.len()
            && matches!(self.s[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.pos]).expect("ascii");
        text.parse::<f64>().map_err(|_| anyhow::anyhow!("bad number '{text}'"))
    }

    fn keyword(&mut self, word: &str, v: Value) -> Result<Value> {
        ensure!(
            self.s[self.pos..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn value(&mut self, depth: usize) -> Result<Value> {
        ensure!(depth < 4, "message nests too deep");
        match self.peek() {
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.keyword("true", Value::Bool(true)),
            Some(b'f') => self.keyword("false", Value::Bool(false)),
            Some(b'n') => self.keyword("null", Value::Null),
            Some(b'[') => {
                self.expect(b'[')?;
                let mut items = Vec::new();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                loop {
                    items.push(self.value(depth + 1)?);
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Value::Arr(items));
                        }
                        other => bail!("expected ',' or ']', found {other:?}"),
                    }
                }
            }
            Some(b'-' | b'0'..=b'9') => Ok(Value::Num(self.number()?)),
            other => bail!("unexpected value start {other:?} at byte {}", self.pos),
        }
    }
}

/// Parse one protocol line into a [`Message`].
pub fn parse_message(line: &str) -> Result<Message> {
    let mut p = Parser { s: line.as_bytes(), pos: 0 };
    p.expect(b'{')?;
    let mut fields = BTreeMap::new();
    if p.peek() == Some(b'}') {
        p.pos += 1;
        return Ok(Message { fields });
    }
    loop {
        let key = p.string()?;
        p.expect(b':')?;
        let val = p.value(0)?;
        fields.insert(key, val);
        match p.peek() {
            Some(b',') => p.pos += 1,
            Some(b'}') => {
                p.pos += 1;
                break;
            }
            other => bail!("expected ',' or '}}', found {other:?}"),
        }
    }
    p.skip_ws();
    ensure!(p.pos == p.s.len(), "trailing bytes after message");
    Ok(Message { fields })
}

// --- serialization ----------------------------------------------------

/// Escape a string for embedding in a protocol line.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push(' '),
            c => out.push(c),
        }
    }
    out
}

/// Incremental builder for one flat response/request object.
#[derive(Debug)]
pub struct ObjBuilder {
    buf: String,
}

impl ObjBuilder {
    /// Start an empty object.
    pub fn new() -> ObjBuilder {
        ObjBuilder { buf: String::from("{") }
    }

    fn key(&mut self, k: &str) {
        if self.buf.len() > 1 {
            self.buf.push(',');
        }
        self.buf.push('"');
        self.buf.push_str(&escape(k));
        self.buf.push_str("\":");
    }

    /// Add a string field.
    pub fn str_field(mut self, k: &str, v: &str) -> ObjBuilder {
        self.key(k);
        self.buf.push('"');
        self.buf.push_str(&escape(v));
        self.buf.push('"');
        self
    }

    /// Add an unsigned integer field.
    pub fn num_field(mut self, k: &str, v: u64) -> ObjBuilder {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Add a boolean field.
    pub fn bool_field(mut self, k: &str, v: bool) -> ObjBuilder {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// Add an array-of-strings field.
    pub fn list_field(mut self, k: &str, items: &[String]) -> ObjBuilder {
        self.key(k);
        self.buf.push('[');
        for (i, it) in items.iter().enumerate() {
            if i > 0 {
                self.buf.push(',');
            }
            self.buf.push('"');
            self.buf.push_str(&escape(it));
            self.buf.push('"');
        }
        self.buf.push(']');
        self
    }

    /// Close the object and return the line (no trailing newline).
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

impl Default for ObjBuilder {
    fn default() -> ObjBuilder {
        ObjBuilder::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_compile_request() {
        let m = parse_message(
            r#"{"cmd":"compile","model":"/tmp/m.qmodel","arch":["a.yaml","b.yaml"],"profile":6,"fast":true}"#,
        )
        .unwrap();
        assert_eq!(m.cmd(), "compile");
        assert_eq!(m.str_field("model"), Some("/tmp/m.qmodel"));
        assert_eq!(m.str_list("arch"), vec!["a.yaml".to_string(), "b.yaml".to_string()]);
        assert_eq!(m.num_field("profile"), Some(6.0));
        assert_eq!(m.bool_field("fast"), Some(true));
        assert_eq!(m.str_field("missing"), None);
    }

    #[test]
    fn single_string_arch_becomes_one_element_list() {
        let m = parse_message(r#"{"cmd":"compile","arch":"one.yaml"}"#).unwrap();
        assert_eq!(m.str_list("arch"), vec!["one.yaml".to_string()]);
        assert!(m.str_list("nope").is_empty());
    }

    #[test]
    fn whitespace_escapes_and_empty_object() {
        let m = parse_message(" { \"cmd\" : \"x y\\n\\\"z\\\"\" , \"n\" : -2.5 } ").unwrap();
        assert_eq!(m.cmd(), "x y\n\"z\"");
        assert_eq!(m.num_field("n"), Some(-2.5));
        assert_eq!(parse_message("{}").unwrap().cmd(), "");
    }

    #[test]
    fn rejects_malformed_lines() {
        for bad in [
            "",
            "not json",
            "{\"a\":}",
            "{\"a\":1",
            "{\"a\":1} trailing",
            "{\"a\":\"unterminated}",
            "{\"a\":[1,}",
        ] {
            assert!(parse_message(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn builder_roundtrips_through_parser() {
        let line = ObjBuilder::new()
            .bool_field("ok", true)
            .str_field("cmd", "compile")
            .num_field("items", 42)
            .str_field("path", "/a \"b\"\\c")
            .list_field("arch", &["x.yaml".to_string(), "y.yaml".to_string()])
            .finish();
        let m = parse_message(&line).unwrap();
        assert_eq!(m.bool_field("ok"), Some(true));
        assert_eq!(m.num_field("items"), Some(42.0));
        assert_eq!(m.str_field("path"), Some("/a \"b\"\\c"));
        assert_eq!(m.str_list("arch").len(), 2);
    }

    #[test]
    fn utf8_strings_survive() {
        let line = ObjBuilder::new().str_field("name", "tölpel-机器").finish();
        let m = parse_message(&line).unwrap();
        assert_eq!(m.str_field("name"), Some("tölpel-机器"));
    }
}
