//! Reporting helpers shared by the benches: table rendering of latency
//! comparisons in the paper's format.

use crate::sim::report::RunReport;
use crate::util::table::{commafy, Table};

/// One Table-2-style row: a workload and its latency under each backend.
#[derive(Debug, Clone)]
pub struct LatencyRow {
    pub workload: String,
    pub c_toolchain: u64,
    pub byoc_uma: u64,
    pub proposed: u64,
}

/// Render rows in the layout of the paper's Table 2.
pub fn table2(rows: &[LatencyRow]) -> Table {
    let mut t = Table::new("Table 2: Deployment results — Latency (Cycles)").header(&[
        "Workload",
        "C-based Toolchain",
        "Proposed",
        "BYOC/UMA Backend",
        "BYOC/Proposed",
    ]);
    for r in rows {
        t.row(vec![
            r.workload.clone(),
            commafy(r.c_toolchain),
            commafy(r.proposed),
            commafy(r.byoc_uma),
            format!("{:.2}x", r.byoc_uma as f64 / r.proposed as f64),
        ]);
    }
    t
}

/// One-line textual summary of a run report.
pub fn describe(name: &str, rep: &RunReport, pe_dim: usize) -> String {
    format!(
        "{name}: {} cycles (host {}), util {:.1}%, dram {}/{} B, {} cmds",
        commafy(rep.cycles),
        commafy(rep.host_cycles),
        rep.utilization(pe_dim) * 100.0,
        commafy(rep.dram_read_bytes),
        commafy(rep.dram_write_bytes),
        commafy(rep.issued_commands),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_formats_ratio() {
        let rows = vec![LatencyRow {
            workload: "(64, 64, 64)".into(),
            c_toolchain: 69_994,
            byoc_uma: 160_163,
            proposed: 69_995,
        }];
        let t = table2(&rows);
        let s = t.render();
        assert!(s.contains("2.29x"));
        assert!(s.contains("160,163"));
    }
}
