//! Seeded random model-graph generator.
//!
//! [`gen_case`] draws a *valid* quantized GEMM-stack model — randomized
//! layer count, dimensions (including degenerate 1s and non-power-of-two
//! sizes), requant parameters and activations — plus one or more input
//! vectors, entirely from a [`Rng`] seeded with the case seed. The same
//! seed always yields byte-identical models and inputs, which is what
//! makes every fuzz finding replayable from its seed alone.
//!
//! Every generated model parses back through
//! [`crate::relay::import::parse_qmodel`] (chain-consistent dims, valid
//! activation tags, `lo <= hi` on clip layers — `clamp` panics
//! otherwise), so the generator can only produce graphs the compiler is
//! *supposed* to handle; any downstream failure is a compiler bug, not a
//! malformed input.

use crate::relay::import::{QLayer, QModel};
use crate::util::prng::Rng;

/// Bounds of the random model space. The defaults keep a single case
/// cheap enough to compile through every oracle axis in milliseconds
/// while still covering degenerate (1) and awkward (odd, non-power-of-
/// two) dimensions.
#[derive(Debug, Clone, Copy)]
pub struct GenOptions {
    /// Maximum number of dense layers per model (≥ 1).
    pub max_layers: usize,
    /// Maximum layer width (input and output dims; ≥ 1).
    pub max_dim: usize,
    /// Maximum batch size (≥ 1).
    pub max_batch: usize,
    /// Maximum number of input vectors per case (≥ 1).
    pub max_inputs: usize,
}

impl Default for GenOptions {
    fn default() -> GenOptions {
        GenOptions { max_layers: 4, max_dim: 64, max_batch: 4, max_inputs: 3 }
    }
}

/// One generated differential-test case: the seed it came from, a valid
/// quantized model, and the input vectors to run it on.
#[derive(Debug, Clone)]
pub struct FuzzCase {
    /// The case seed (regenerates the case via [`gen_case`]).
    pub seed: u64,
    /// The generated quantized GEMM stack.
    pub model: QModel,
    /// Input vectors, each `batch * in_dim` int8 elements.
    pub inputs: Vec<Vec<i8>>,
}

impl FuzzCase {
    /// `batch * in_dim` — the length every input vector must have.
    pub fn input_elems(&self) -> usize {
        self.model.batch * self.model.layers[0].in_dim
    }
}

/// One random dimension: mixes degenerate 1s, tiny odd sizes, arbitrary
/// non-power-of-two widths and PE-aligned sizes. Always in
/// `[1, max_dim]`.
fn dim(rng: &mut Rng, max_dim: usize) -> usize {
    let d = if rng.chance(0.12) {
        1
    } else if rng.chance(0.35) {
        rng.range(2, max_dim.max(2).min(8))
    } else if rng.chance(0.5) {
        rng.range(2, max_dim.max(2))
    } else {
        *rng.pick(&[8usize, 16, 24, 32, 48, 64])
    };
    d.clamp(1, max_dim.max(1))
}

/// One requant scale: occasionally the identity (1.0, so in-range
/// accumulators pass through and large ones hit the i8 rails), otherwise
/// a typical small rescale.
fn requant_scale(rng: &mut Rng) -> f32 {
    if rng.chance(0.1) {
        1.0
    } else {
        (0.004 + rng.f64() * 0.12) as f32
    }
}

/// One bias value: mostly moderate, occasionally large enough to force
/// saturation at a rail through any requant scale.
fn bias_value(rng: &mut Rng) -> i32 {
    if rng.chance(0.05) {
        rng.below(1_000_001) as i32 - 500_000
    } else {
        rng.below(2_001) as i32 - 1_000
    }
}

/// Generate the case for `seed`. Deterministic: the same seed and
/// options always produce byte-identical model and inputs.
pub fn gen_case(seed: u64, opts: &GenOptions) -> FuzzCase {
    let mut rng = Rng::new(seed);
    let n_layers = rng.range(1, opts.max_layers.max(1));
    let batch = if rng.chance(0.25) { 1 } else { rng.range(1, opts.max_batch.max(1)) };

    // The layer-width chain (n_layers + 1 widths; adjacent layers share
    // a width, so the model is chain-consistent by construction).
    let widths: Vec<usize> = (0..=n_layers).map(|_| dim(&mut rng, opts.max_dim)).collect();

    let layers: Vec<QLayer> = widths
        .windows(2)
        .map(|w| {
            let (in_dim, out_dim) = (w[0], w[1]);
            let requant = requant_scale(&mut rng);
            let act = rng.below(3) as u8;
            // `i8::clamp` panics when lo > hi, so a clip layer must
            // always carry an ordered range (lo == hi is legal and a
            // useful degenerate case).
            let (a, b) = (rng.i8(), rng.i8());
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let weight = rng.i8_vec(out_dim * in_dim);
            let bias = (0..out_dim).map(|_| bias_value(&mut rng)).collect();
            QLayer { in_dim, out_dim, requant, out_scale: 0.1, act, lo, hi, weight, bias }
        })
        .collect();

    let model = QModel { batch, input_scale: 0.05, layers };
    let elems = batch * widths[0];
    let n_inputs = rng.range(1, opts.max_inputs.max(1));
    let inputs = (0..n_inputs)
        .map(|_| {
            if rng.chance(0.08) {
                vec![0i8; elems] // all-zero input: bias-only data path
            } else {
                rng.i8_vec(elems)
            }
        })
        .collect();

    FuzzCase { seed, model, inputs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::import::{parse_qmodel, to_qnn_graph, write_qmodel};

    #[test]
    fn same_seed_same_case() {
        let opts = GenOptions::default();
        for seed in [0u64, 1, 42, u64::MAX] {
            let a = gen_case(seed, &opts);
            let b = gen_case(seed, &opts);
            assert_eq!(write_qmodel(&a.model), write_qmodel(&b.model));
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.seed, seed);
        }
    }

    #[test]
    fn generated_models_are_valid() {
        // Every generated model must survive the importer's validation
        // (chain consistency, positive dims, act tags, exact byte
        // length) and build a QNN graph.
        let opts = GenOptions::default();
        for seed in 0..200u64 {
            let case = gen_case(seed, &opts);
            let bytes = write_qmodel(&case.model);
            let back = parse_qmodel(&bytes)
                .unwrap_or_else(|e| panic!("seed {seed}: generated model invalid: {e}"));
            assert_eq!(back.layers.len(), case.model.layers.len());
            to_qnn_graph(&case.model)
                .unwrap_or_else(|e| panic!("seed {seed}: graph build failed: {e}"));
            for l in &case.model.layers {
                assert!(l.lo <= l.hi, "seed {seed}: clip range must be ordered");
                assert!((1..=opts.max_dim).contains(&l.in_dim));
                assert!((1..=opts.max_dim).contains(&l.out_dim));
            }
            assert!(!case.inputs.is_empty());
            for x in &case.inputs {
                assert_eq!(x.len(), case.input_elems());
            }
        }
    }

    #[test]
    fn space_covers_degenerate_and_awkward_shapes() {
        let opts = GenOptions::default();
        let (mut ones, mut odd, mut multi_layer, mut zero_input, mut identity) =
            (false, false, false, false, false);
        for seed in 0..400u64 {
            let case = gen_case(seed, &opts);
            for l in &case.model.layers {
                ones |= l.in_dim == 1 || l.out_dim == 1;
                odd |= l.in_dim % 2 == 1 && l.in_dim > 1;
                identity |= l.requant == 1.0;
            }
            multi_layer |= case.model.layers.len() > 1;
            zero_input |= case.inputs.iter().any(|x| x.iter().all(|&v| v == 0));
        }
        assert!(ones, "degenerate dim-1 layers must appear");
        assert!(odd, "odd non-power-of-two dims must appear");
        assert!(multi_layer, "multi-layer stacks must appear");
        assert!(zero_input, "all-zero inputs must appear");
        assert!(identity, "identity requant (scale 1.0) must appear");
    }
}
