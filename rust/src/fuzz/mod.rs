//! Differential fuzzing: seeded graph generator, multi-axis oracle,
//! deterministic minimizer, replayable reproducer corpus.
//!
//! The pieces compose into one loop ([`run_fuzz`]):
//!
//! 1. [`gen::gen_case`] derives a valid random quantized GEMM-stack
//!    model (plus inputs) from a case seed,
//! 2. [`oracle::check_case`] compiles it through every configuration
//!    axis the repo makes promises about and checks each promise,
//! 3. on failure, [`minimize::minimize`] shrinks the case while the
//!    *same axis* keeps failing, and
//! 4. [`corpus::save_repro`] archives the minimized case as a
//!    replayable `.repro` file.
//!
//! Everything is deterministic: the same `--seed` and `--cases` visit
//! the same models, and a failing seed always minimizes to the same
//! reproducer. Case seeds are derived from the base seed with the same
//! splitmix-style mix `util/prop.rs` uses, so a failing case `i` can
//! also be replayed directly via its printed case seed.

pub mod corpus;
pub mod gen;
pub mod minimize;
pub mod oracle;

pub use corpus::{
    load_repro, load_repro_tagged, parse_repro, parse_repro_tagged, repro_file_name,
    save_repro, save_repro_tagged, write_repro, write_repro_tagged,
};
pub use gen::{gen_case, FuzzCase, GenOptions};
pub use minimize::{minimize, MinimizeStats};
pub use oracle::{bigarray_desc, check_case, multi_target_pairings, Failure, Verdict};

use std::path::{Path, PathBuf};

use anyhow::Result;

/// Options for one fuzzing run.
#[derive(Debug, Clone)]
pub struct FuzzOptions {
    /// Number of cases to generate and check.
    pub cases: u64,
    /// Base seed; case `i` uses [`case_seed`]`(seed, i)`.
    pub seed: u64,
    /// Bounds of the random model space.
    pub gen: GenOptions,
    /// Where to archive minimized reproducers (`None`: don't write).
    pub out_dir: Option<PathBuf>,
}

impl Default for FuzzOptions {
    fn default() -> FuzzOptions {
        FuzzOptions { cases: 100, seed: 0, gen: GenOptions::default(), out_dir: None }
    }
}

/// One minimized finding from a fuzzing run.
#[derive(Debug, Clone)]
pub struct FuzzFinding {
    /// The case seed that first hit the failure.
    pub seed: u64,
    /// Base seed that regenerates this exact case as case 0 of a
    /// one-case run (`fuzz --cases 1 --seed <replay_base>`); equals the
    /// run's base seed plus the case index, mirroring [`case_seed`].
    pub replay_base: u64,
    /// The oracle axis that broke (stable identifier, see [`oracle`]).
    pub axis: &'static str,
    /// Which backend (registry id) or multi-target pairing broke the
    /// axis; empty for backend-independent axes. Archived into the
    /// `.repro` provenance field.
    pub backend: String,
    /// Mismatch detail *after* minimization.
    pub detail: String,
    /// The minimized reproducer case.
    pub minimized: FuzzCase,
    /// Where the reproducer was archived, when `out_dir` was set.
    pub repro_path: Option<PathBuf>,
    /// Shrink counters.
    pub stats: MinimizeStats,
}

/// The result of a fuzzing run.
#[derive(Debug, Clone, Default)]
pub struct FuzzSummary {
    /// Cases generated and checked.
    pub cases: u64,
    /// Minimized findings, in discovery order.
    pub findings: Vec<FuzzFinding>,
}

impl FuzzSummary {
    /// True when no case broke any invariant.
    pub fn passed(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable summary (one line per finding).
    pub fn render(&self) -> String {
        let mut s = format!(
            "fuzz: {} cases, {} finding{}\n",
            self.cases,
            self.findings.len(),
            if self.findings.len() == 1 { "" } else { "s" }
        );
        for f in &self.findings {
            s.push_str(&format!(
                "  seed {:#018x} axis {}{} ({} layers, {} shrinks): {}\n",
                f.seed,
                f.axis,
                if f.backend.is_empty() { String::new() } else { format!(" [{}]", f.backend) },
                f.minimized.model.layers.len(),
                f.stats.accepted,
                f.detail
            ));
            if let Some(p) = &f.repro_path {
                s.push_str(&format!("    reproducer: {}\n", p.display()));
            }
            s.push_str(&format!("    replay: tvm-accel fuzz --cases 1 --seed {}\n", f.replay_base));
        }
        s
    }
}

/// The seed of case `i` in a run with base seed `base` — the same
/// splitmix-style derivation `util/prop.rs` uses, so neighbouring cases
/// land far apart in seed space.
pub fn case_seed(base: u64, i: u64) -> u64 {
    base.wrapping_add(i).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// The detail string of the axis failure `case` currently produces, if
/// it is the given axis on the given backend (so a shrink that trades
/// one backend's bug for another's is rejected too).
fn axis_detail(case: &FuzzCase, axis: &'static str, backend: &str) -> Option<String> {
    match check_case(case) {
        Verdict::Fail(f) if f.axis == axis && f.backend == backend => Some(f.detail),
        _ => None,
    }
}

/// Generate `opts.cases` cases, check each through every oracle axis,
/// and minimize + archive every failure. Deterministic for fixed
/// options.
pub fn run_fuzz(opts: &FuzzOptions) -> Result<FuzzSummary> {
    let mut summary = FuzzSummary::default();
    for i in 0..opts.cases {
        if i > 0 && i % 100 == 0 {
            eprintln!("fuzz: {i}/{} cases, {} findings", opts.cases, summary.findings.len());
        }
        let seed = case_seed(opts.seed, i);
        let case = gen_case(seed, &opts.gen);
        summary.cases += 1;
        let failure = match check_case(&case) {
            Verdict::Pass => continue,
            Verdict::Fail(f) => f,
        };
        eprintln!(
            "fuzz: case {i} (seed {seed:#018x}) broke axis {} on backend '{}': {} — minimizing",
            failure.axis, failure.backend, failure.detail
        );
        let axis = failure.axis;
        let backend = failure.backend.clone();
        let (minimized, stats) =
            minimize(&case, |c| axis_detail(c, axis, &backend).is_some());
        let detail = axis_detail(&minimized, axis, &backend).unwrap_or(failure.detail);
        let repro_path = match &opts.out_dir {
            Some(dir) => Some(save_repro_tagged(&minimized, &backend, dir)?),
            None => None,
        };
        summary.findings.push(FuzzFinding {
            seed,
            replay_base: opts.seed.wrapping_add(i),
            axis,
            backend,
            detail,
            minimized,
            repro_path,
            stats,
        });
    }
    Ok(summary)
}

/// Replay one archived reproducer file through every oracle axis.
pub fn replay_file(path: &Path) -> Result<Verdict> {
    let case = load_repro(path)?;
    Ok(check_case(&case))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::eval::eval;
    use crate::relay::import::{to_qnn_graph, write_qmodel, QModel};
    use crate::relay::{Tensor, TensorData};
    use std::collections::BTreeMap;

    #[test]
    fn case_seeds_are_spread_and_deterministic() {
        let a: Vec<u64> = (0..8).map(|i| case_seed(7, i)).collect();
        let b: Vec<u64> = (0..8).map(|i| case_seed(7, i)).collect();
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), a.len(), "case seeds must not collide");
    }

    #[test]
    fn replay_base_regenerates_the_case() {
        // `fuzz --cases 1 --seed (base + i)` must visit exactly the case
        // that `--cases N --seed base` hit at index i.
        for (base, i) in [(7u64, 3u64), (0, 0), (123, 499), (u64::MAX, 9)] {
            assert_eq!(case_seed(base, i), case_seed(base.wrapping_add(i), 0));
        }
    }

    #[test]
    fn small_run_is_clean_and_deterministic() {
        // A miniature end-to-end run: every case must pass every axis,
        // twice, identically.
        let opts = FuzzOptions {
            cases: 3,
            seed: 41,
            gen: GenOptions { max_layers: 2, max_dim: 12, max_batch: 2, max_inputs: 2 },
            out_dir: None,
        };
        let a = run_fuzz(&opts).unwrap();
        let b = run_fuzz(&opts).unwrap();
        assert!(a.passed(), "{}", a.render());
        assert_eq!(a.cases, 3);
        assert_eq!(b.findings.len(), a.findings.len());
    }

    /// Interpret `model` with every bias zeroed — a stand-in for an
    /// injected eval bug ("bias is ignored"), kept out of the shipping
    /// interpreter.
    fn buggy_reference(model: &QModel, input: &[i8]) -> Vec<i8> {
        let mut broken = model.clone();
        for l in &mut broken.layers {
            l.bias.iter_mut().for_each(|b| *b = 0);
        }
        let g = to_qnn_graph(&broken).unwrap();
        let mut m = BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(
                vec![model.batch, model.layers[0].in_dim],
                TensorData::I8(input.to_vec()),
            )
            .unwrap(),
        );
        eval(&g, &m).unwrap()[0].data.as_i8().unwrap().to_vec()
    }

    /// The acceptance drill from the issue: a differential predicate
    /// against a deliberately broken reference must be caught and
    /// minimized to a tiny deterministic reproducer.
    #[test]
    fn injected_eval_bug_is_caught_and_minimized_small() {
        let opts = GenOptions { max_layers: 3, max_dim: 12, max_batch: 2, max_inputs: 2 };
        let bug_visible = |c: &FuzzCase| {
            let g = match to_qnn_graph(&c.model) {
                Ok(g) => g,
                Err(_) => return false,
            };
            c.inputs.iter().any(|x| {
                let mut m = BTreeMap::new();
                m.insert(
                    "x".to_string(),
                    Tensor::new(
                        vec![c.model.batch, c.model.layers[0].in_dim],
                        TensorData::I8(x.clone()),
                    )
                    .unwrap(),
                );
                let good = eval(&g, &m).unwrap()[0].data.as_i8().unwrap().to_vec();
                good != buggy_reference(&c.model, x)
            })
        };
        // Find a case where the injected bug changes the output.
        let case = (0..200u64)
            .map(|s| gen_case(case_seed(7, s), &opts))
            .find(|c| bug_visible(c))
            .expect("a bias-sensitive case exists in 200 seeds");
        let (a, _) = minimize(&case, bug_visible);
        let (b, _) = minimize(&case, bug_visible);
        assert!(bug_visible(&a), "minimized case must still expose the bug");
        assert!(
            a.model.layers.len() <= 2,
            "expected ≤ 2 layers after minimization, got {}",
            a.model.layers.len()
        );
        // Same seed in, same reproducer out — byte-identical.
        assert_eq!(write_qmodel(&a.model), write_qmodel(&b.model));
        assert_eq!(a.inputs, b.inputs);
        assert_eq!(write_repro(&a), write_repro(&b));
    }

    #[test]
    fn findings_are_archived_and_replayable() {
        // Exercise the archive path without a real compiler bug: save a
        // generated case as a reproducer and replay it through the
        // oracle end to end.
        let opts = GenOptions { max_layers: 2, max_dim: 10, max_batch: 2, max_inputs: 1 };
        let case = gen_case(3, &opts);
        let dir = std::env::temp_dir()
            .join(format!("tvm-accel-fuzz-replay-{}", std::process::id()));
        let path = save_repro(&case, &dir).unwrap();
        let verdict = replay_file(&path).unwrap();
        assert!(verdict.passed(), "{verdict:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
