//! Deterministic shrinking of failing cases.
//!
//! [`minimize`] repeatedly proposes structurally smaller variants of a
//! failing case — drop a layer, halve a dimension or the batch, drop
//! extra inputs, zero the input data, strip activations — and keeps a
//! variant whenever the caller's predicate says the failure still
//! reproduces. Candidates are generated in a fixed order and the first
//! reproducing one is taken, so the same failing case and predicate
//! always minimize to the same reproducer (same seed in, same
//! reproducer out).
//!
//! Every shrink keeps the model valid: the layer chain stays
//! dimension-consistent (weights are re-sliced or zero-padded when a
//! splice changes a layer's input width), `lo <= hi` is never touched,
//! and inputs are resized to `batch * in_dim`.

use crate::relay::import::QLayer;

use super::gen::FuzzCase;

/// Counters from one minimization run.
#[derive(Debug, Clone, Copy, Default)]
pub struct MinimizeStats {
    /// Shrink candidates tried (predicate invocations, minus the initial
    /// reproduction check).
    pub attempts: u64,
    /// Candidates that still reproduced and were kept.
    pub accepted: u64,
}

/// Set a layer's input width, truncating or zero-padding each weight row
/// (TFLite `[out, in]` layout: row r holds the weights of output r).
fn resize_layer_input(l: &mut QLayer, new_in: usize) {
    let copy = l.in_dim.min(new_in);
    let mut w = vec![0i8; l.out_dim * new_in];
    for r in 0..l.out_dim {
        w[r * new_in..r * new_in + copy]
            .copy_from_slice(&l.weight[r * l.in_dim..r * l.in_dim + copy]);
    }
    l.weight = w;
    l.in_dim = new_in;
}

/// Truncate a layer's output width: drop weight rows and bias entries
/// past `new_out` (callers shrink only, so `new_out <= out_dim`).
fn truncate_layer_output(l: &mut QLayer, new_out: usize) {
    debug_assert!(new_out <= l.out_dim);
    l.weight.truncate(new_out * l.in_dim);
    l.bias.truncate(new_out);
    l.out_dim = new_out;
}

/// Resize every input vector to the model's current `batch * in_dim`
/// (truncate, then zero-pad).
fn fix_inputs(case: &mut FuzzCase) {
    let want = case.model.batch * case.model.layers[0].in_dim;
    for v in &mut case.inputs {
        v.truncate(want);
        v.resize(want, 0);
    }
}

/// All shrink candidates of `cur`, most aggressive first. Deterministic
/// order; every candidate is a valid case.
fn shrink_candidates(cur: &FuzzCase) -> Vec<FuzzCase> {
    let mut out = Vec::new();
    let n_layers = cur.model.layers.len();

    // 1. Drop one layer (splicing the chain back together).
    if n_layers > 1 {
        for i in 0..n_layers {
            let mut c = cur.clone();
            c.model.layers.remove(i);
            // The old successor (now at index i, if any) must accept the
            // old predecessor's output width.
            if i > 0 && i < c.model.layers.len() {
                let feed = c.model.layers[i - 1].out_dim;
                resize_layer_input(&mut c.model.layers[i], feed);
            }
            fix_inputs(&mut c);
            out.push(c);
        }
    }

    // 2. Halve the batch.
    if cur.model.batch > 1 {
        let mut c = cur.clone();
        c.model.batch /= 2;
        fix_inputs(&mut c);
        out.push(c);
    }

    // 3. Halve the first layer's input width.
    if cur.model.layers[0].in_dim > 1 {
        let mut c = cur.clone();
        let new_in = cur.model.layers[0].in_dim / 2;
        resize_layer_input(&mut c.model.layers[0], new_in);
        fix_inputs(&mut c);
        out.push(c);
    }

    // 4. Halve one layer's output width (and the successor's input).
    for i in 0..n_layers {
        if cur.model.layers[i].out_dim > 1 {
            let mut c = cur.clone();
            let new_out = cur.model.layers[i].out_dim / 2;
            truncate_layer_output(&mut c.model.layers[i], new_out);
            if i + 1 < n_layers {
                resize_layer_input(&mut c.model.layers[i + 1], new_out);
            }
            out.push(c);
        }
    }

    // 5. Drop extra inputs.
    if cur.inputs.len() > 1 {
        let mut c = cur.clone();
        c.inputs.truncate(1);
        out.push(c);
    }

    // 6. Zero the input data.
    if cur.inputs.iter().any(|v| v.iter().any(|&x| x != 0)) {
        let mut c = cur.clone();
        for v in &mut c.inputs {
            v.iter_mut().for_each(|x| *x = 0);
        }
        out.push(c);
    }

    // 7. Strip activations.
    for i in 0..n_layers {
        if cur.model.layers[i].act != 0 {
            let mut c = cur.clone();
            c.model.layers[i].act = 0;
            out.push(c);
        }
    }

    out
}

/// Shrink `case` while `still_fails` keeps returning true, to a fixed
/// point. Returns the original (cloned) case untouched when it does not
/// reproduce under the predicate.
pub fn minimize(
    case: &FuzzCase,
    mut still_fails: impl FnMut(&FuzzCase) -> bool,
) -> (FuzzCase, MinimizeStats) {
    let mut stats = MinimizeStats::default();
    if !still_fails(case) {
        return (case.clone(), stats);
    }
    let mut cur = case.clone();
    loop {
        let mut progressed = false;
        for cand in shrink_candidates(&cur) {
            stats.attempts += 1;
            if still_fails(&cand) {
                cur = cand;
                stats.accepted += 1;
                progressed = true;
                break; // regenerate candidates from the smaller case
            }
        }
        if !progressed {
            return (cur, stats);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fuzz::gen::{gen_case, GenOptions};
    use crate::relay::import::{parse_qmodel, write_qmodel};

    /// A synthetic "bug": fails whenever the model still has a layer
    /// with an odd input width greater than 1.
    fn has_odd_wide_input(c: &FuzzCase) -> bool {
        c.model.layers.iter().any(|l| l.in_dim > 1 && l.in_dim % 2 == 1)
    }

    fn some_failing_case() -> FuzzCase {
        let opts = GenOptions::default();
        (0..)
            .map(|s| gen_case(s, &opts))
            .find(has_odd_wide_input)
            .expect("the space contains odd input widths")
    }

    #[test]
    fn shrinks_stay_valid_models() {
        let opts = GenOptions::default();
        for seed in 0..40u64 {
            let case = gen_case(seed, &opts);
            for cand in shrink_candidates(&case) {
                parse_qmodel(&write_qmodel(&cand.model)).unwrap_or_else(|e| {
                    panic!("seed {seed}: shrink produced an invalid model: {e}")
                });
                for x in &cand.inputs {
                    assert_eq!(x.len(), cand.input_elems());
                }
            }
        }
    }

    #[test]
    fn minimizes_to_fixed_point_deterministically() {
        let case = some_failing_case();
        let (a, stats_a) = minimize(&case, has_odd_wide_input);
        let (b, stats_b) = minimize(&case, has_odd_wide_input);
        assert!(has_odd_wide_input(&a), "result must still fail");
        assert_eq!(write_qmodel(&a.model), write_qmodel(&b.model));
        assert_eq!(a.inputs, b.inputs);
        assert_eq!(stats_a.accepted, stats_b.accepted);
        // Fixed point: no shrink of the result reproduces.
        assert!(shrink_candidates(&a).iter().all(|c| !has_odd_wide_input(c)));
        // And it genuinely shrank from a multi-property random case.
        assert!(a.model.layers.len() <= case.model.layers.len());
        assert!(stats_a.attempts >= stats_a.accepted);
    }

    #[test]
    fn non_reproducing_case_is_returned_unchanged() {
        let case = gen_case(5, &GenOptions::default());
        let (out, stats) = minimize(&case, |_| false);
        assert_eq!(write_qmodel(&out.model), write_qmodel(&case.model));
        assert_eq!(stats.accepted, 0);
    }
}
