//! The differential oracle: one case, every configuration axis.
//!
//! [`check_case`] compiles the case's model through each axis the repo
//! makes promises about, executes on the simulator, and checks every
//! promise against [`crate::relay::eval`] (element-exactness) or against
//! a sibling configuration (cross-config invariants). The single-target
//! axes iterate the backend registry ([`crate::backend::backends`]), so
//! a newly registered target family is fuzzed without touching this
//! module:
//!
//! | axis                  | invariant checked                                |
//! |-----------------------|--------------------------------------------------|
//! | `exact/single`        | each registered backend's output == interpreter  |
//! | `timing/data-independent` | same program, same cycles for every input    |
//! | `bytes/pruned-vs-serial` | serial sweep emits a byte-identical program   |
//! | `exact/residency-off` | `cross_layer: false` output == interpreter       |
//! | `residency/dram-transfer` | residency-on DRAM-transfer cycles ≤ off      |
//! | `exact/multi`         | each multi-target partitioning == interpreter    |
//! | `report/issued-commands` | merged `issued_commands` == accel insn count  |
//! | `report/loop-ws`      | merged `loop_ws` count == program histogram      |
//! | `report/host-counts`  | merged per-host-op counts == program histogram   |
//! | `batch/exact`         | `run_batch` outputs == per-input `run` outputs   |
//! | `batch/serial-sum`    | `serial_cycles` == Σ per-inference cycles        |
//! | `batch/pipelined-le-serial` | pipelined ≤ serial (single and multi)      |
//! | `async/overlap-le-serial` | overlapped makespan nonzero and ≤ serial on every multi run |
//!
//! The multi-target axis checks every pairing in
//! [`multi_target_pairings`]: the heterogeneous systolic pair
//! (gemmini + bigarray-os) and the cross-family pair (gemmini + vector).
//! Each [`Failure`] records which backend (or pairing) broke in
//! [`Failure::backend`]; the minimizer shrinks only while the same
//! axis *and* backend keep failing.
//!
//! The byte-identity pair compiles through two *fresh* compilers: the
//! `pruned`/`parallel` sweep knobs are deliberately excluded from the
//! schedule-cache key (they promise byte-identical results), so reusing
//! one compiler would let the second compile hit the first's cache and
//! the comparison would be vacuous.

use std::collections::BTreeMap;

use crate::accel::gemmini::{desc_for_arch, gemmini_desc};
use crate::accel::AccelDesc;
use crate::arch::ArchDesc;
use crate::backend::vector::vector_desc;
use crate::pipeline::{CompileOptions, Compiler, MultiCompiler};
use crate::relay::eval::eval;
use crate::relay::import::to_qnn_graph;
use crate::relay::{Graph, Tensor, TensorData};
use crate::scheduler::sweep::SweepOptions;
use crate::sim::report::RunReport;
use crate::sim::Simulator;

use super::gen::FuzzCase;

/// The verdict for one case across every axis.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Verdict {
    /// Every invariant held on every axis.
    Pass,
    /// The first invariant that broke.
    Fail(Failure),
}

impl Verdict {
    /// True for [`Verdict::Pass`].
    pub fn passed(&self) -> bool {
        matches!(self, Verdict::Pass)
    }
}

/// One broken invariant: which axis caught it, on which backend, and the
/// details.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Failure {
    /// Stable axis identifier (see the module table). The minimizer
    /// shrinks while the *same axis* keeps failing, so a shrink that
    /// trades one bug for a different one is rejected.
    pub axis: &'static str,
    /// Which backend (registry id) or multi-target pairing
    /// (`"gemmini+vector"`) broke the invariant. Empty for
    /// backend-independent axes (import, reference eval). Archived into
    /// the `.repro` provenance field.
    pub backend: String,
    /// Human-readable mismatch description.
    pub detail: String,
}

fn fail(axis: &'static str, detail: impl Into<String>) -> Verdict {
    fail_on("", axis, detail)
}

fn fail_on(backend: &str, axis: &'static str, detail: impl Into<String>) -> Verdict {
    Verdict::Fail(Failure { axis, backend: backend.to_string(), detail: detail.into() })
}

/// The options every oracle compile uses (identical across the
/// byte-identity pair — `profile_candidates` is part of the cache key
/// and of the selection, so it must not differ).
fn fuzz_options() -> CompileOptions {
    CompileOptions { profile_candidates: 2, ..CompileOptions::default() }
}

/// The second multi-target candidate: a 32×32 output-stationary array
/// with bigger scratchpad and wider DMA (the `bigarray-os` configuration
/// the heterogeneous tests use).
pub fn bigarray_desc() -> anyhow::Result<AccelDesc> {
    let mut arch = ArchDesc::gemmini();
    arch.name = "bigarray-os".into();
    arch.pe_dim = 32;
    arch.constraints.insn_tile_limit = 32;
    arch.dataflows = vec![crate::arch::Dataflow::OutputStationary];
    arch.levels[1].size_bytes = 131072; // accumulator
    arch.levels[2].size_bytes = 524288; // scratchpad
    arch.dma.bytes_per_cycle = 32;
    desc_for_arch("bigarray-os", arch)
}

/// Every multi-target pairing the oracle compiles: `(tag, targets)`.
/// The tag names the pairing in [`Failure::backend`].
pub fn multi_target_pairings() -> anyhow::Result<Vec<(&'static str, Vec<AccelDesc>)>> {
    let gem = gemmini_desc()?;
    Ok(vec![
        ("gemmini+bigarray-os", vec![gem.clone(), bigarray_desc()?]),
        ("gemmini+vector", vec![gem, vector_desc()?]),
    ])
}

/// First index where two int8 vectors differ, with values (for the
/// failure detail).
fn first_diff(got: &[i8], want: &[i8]) -> String {
    if got.len() != want.len() {
        return format!("length {} vs {}", got.len(), want.len());
    }
    match got.iter().zip(want).position(|(a, b)| a != b) {
        Some(i) => format!("elem {i}: got {} want {}", got[i], want[i]),
        None => "identical".to_string(),
    }
}

/// The interpreter's output for one input vector.
fn reference_output(case: &FuzzCase, graph: &Graph, input: &[i8]) -> anyhow::Result<Vec<i8>> {
    let mut m = BTreeMap::new();
    m.insert(
        "x".to_string(),
        Tensor::new(
            vec![case.model.batch, case.model.layers[0].in_dim],
            TensorData::I8(input.to_vec()),
        )?,
    );
    let out = eval(graph, &m)?;
    Ok(out[0].data.as_i8()?.to_vec())
}

/// Check the merged [`RunReport`] of a full-program execution against
/// the instruction stream it claims to describe.
fn check_report_counters(
    backend: &str,
    rep: &RunReport,
    program: &crate::isa::program::Program,
) -> Option<Verdict> {
    let accel = program.accel_insn_count() as u64;
    if rep.issued_commands != accel {
        return Some(fail_on(
            backend,
            "report/issued-commands",
            format!(
                "merged report issued {} commands, program has {accel} accel instructions",
                rep.issued_commands
            ),
        ));
    }
    let hist = program.histogram();
    let hist_loop_ws = hist.get("loop_ws").copied().unwrap_or(0) as u64;
    let rep_loop_ws = rep.insn_counts.get("loop_ws").copied().unwrap_or(0);
    if rep_loop_ws != hist_loop_ws {
        return Some(fail_on(
            backend,
            "report/loop-ws",
            format!("report counted {rep_loop_ws} loop_ws, histogram has {hist_loop_ws}"),
        ));
    }
    // Every host op executes exactly once per run, so the merged report's
    // per-mnemonic counts must equal the static histogram.
    for (&m, &n) in &hist {
        if !m.starts_with("host.") {
            continue;
        }
        let counted = rep.insn_counts.get(m).copied().unwrap_or(0);
        if counted != n as u64 {
            return Some(fail_on(
                backend,
                "report/host-counts",
                format!("host op {m}: report counted {counted}, histogram has {n}"),
            ));
        }
    }
    None
}

/// Run `case` through every configuration axis. Returns the first
/// broken invariant (backends in registry order, axes in a fixed order,
/// so the verdict is deterministic).
pub fn check_case(case: &FuzzCase) -> Verdict {
    let graph = match to_qnn_graph(&case.model) {
        Ok(g) => g,
        Err(e) => return fail("import", format!("to_qnn_graph: {e:#}")),
    };

    // Reference outputs, one per input vector.
    let mut want = Vec::with_capacity(case.inputs.len());
    for (i, input) in case.inputs.iter().enumerate() {
        match reference_output(case, &graph, input) {
            Ok(o) => want.push(o),
            Err(e) => return fail("reference-eval", format!("input {i}: {e:#}")),
        }
    }

    // Axes exact/single + timing/data-independent, once per registered
    // backend on its default description. The gemmini deployment and
    // reports feed the deeper gemmini-only axes below.
    let mut gemmini = None;
    for b in crate::backend::backends() {
        let id = b.id();
        let accel = match b.default_desc() {
            Ok(a) => a,
            Err(e) => return fail_on(id, "compile/single", format!("default_desc: {e:#}")),
        };
        let sim = Simulator::new(&accel.arch);
        let dep = match Compiler::with_options(accel.clone(), fuzz_options()).compile(&graph)
        {
            Ok(d) => d,
            Err(e) => return fail_on(id, "compile/single", format!("{e:#}")),
        };
        let mut reports = Vec::with_capacity(case.inputs.len());
        for (i, input) in case.inputs.iter().enumerate() {
            match dep.run(&sim, input) {
                Ok((got, rep)) => {
                    if got != want[i] {
                        return fail_on(
                            id,
                            "exact/single",
                            format!("input {i}: {}", first_diff(&got, &want[i])),
                        );
                    }
                    reports.push(rep);
                }
                Err(e) => return fail_on(id, "exact/single", format!("input {i}: run: {e:#}")),
            }
        }
        // Timing is data-independent — same program, same cycles for
        // every input.
        if let Some((i, r)) =
            reports.iter().enumerate().find(|(_, r)| r.cycles != reports[0].cycles)
        {
            return fail_on(
                id,
                "timing/data-independent",
                format!("input {i} took {} cycles, input 0 took {}", r.cycles, reports[0].cycles),
            );
        }
        if id == "gemmini" {
            gemmini = Some((accel, sim, dep, reports));
        }
    }
    let Some((accel, sim, dep, single_reports)) = gemmini else {
        return fail("registry", "no gemmini backend registered");
    };

    // Axis: the serial, unpruned sweep must emit a byte-identical
    // program (fresh compiler: pruned/parallel are excluded from the
    // cache key, so a shared compiler would make this vacuous).
    let serial_opts = CompileOptions {
        sweep: SweepOptions { pruned: false, parallel: false, ..SweepOptions::default() },
        ..fuzz_options()
    };
    match Compiler::with_options(accel.clone(), serial_opts).compile(&graph) {
        Ok(d) => {
            if d.program.items != dep.program.items {
                return fail_on(
                    "gemmini",
                    "bytes/pruned-vs-serial",
                    format!(
                        "pruned sweep emitted {} items, serial emitted {} (first diff at {:?})",
                        dep.program.items.len(),
                        d.program.items.len(),
                        dep.program.items.iter().zip(&d.program.items).position(|(a, b)| a != b)
                    ),
                );
            }
        }
        Err(e) => return fail_on("gemmini", "compile/serial", format!("{e:#}")),
    }

    // Axis: cross-layer residency off — still element-exact, and the
    // residency-on deployment never moves more DRAM-transfer cycles.
    let no_res_opts = CompileOptions { cross_layer: false, ..fuzz_options() };
    match Compiler::with_options(accel.clone(), no_res_opts).compile(&graph) {
        Ok(d) => {
            for (i, input) in case.inputs.iter().enumerate() {
                match d.run(&sim, input) {
                    Ok((got, rep)) => {
                        if got != want[i] {
                            return fail_on(
                                "gemmini",
                                "exact/residency-off",
                                format!("input {i}: {}", first_diff(&got, &want[i])),
                            );
                        }
                        if i == 0
                            && single_reports[0].dram_transfer_cycles > rep.dram_transfer_cycles
                        {
                            return fail_on(
                                "gemmini",
                                "residency/dram-transfer",
                                format!(
                                    "residency-on spent {} DRAM-transfer cycles, off spent {}",
                                    single_reports[0].dram_transfer_cycles, rep.dram_transfer_cycles
                                ),
                            );
                        }
                    }
                    Err(e) => {
                        return fail_on(
                            "gemmini",
                            "exact/residency-off",
                            format!("input {i}: run: {e:#}"),
                        )
                    }
                }
            }
        }
        Err(e) => return fail_on("gemmini", "compile/residency-off", format!("{e:#}")),
    }

    // Axis: every multi-target pairing — element-exact, report counters
    // consistent, pipelined batch never slower than serial.
    let refs: Vec<&[i8]> = case.inputs.iter().map(|v| v.as_slice()).collect();
    let pairings = match multi_target_pairings() {
        Ok(p) => p,
        Err(e) => return fail("compile/multi", format!("pairings: {e:#}")),
    };
    for (tag, targets) in pairings {
        let multi = MultiCompiler::with_options(targets, fuzz_options());
        let multi = match multi.and_then(|m| m.compile(&graph)) {
            Ok(d) => d,
            Err(e) => return fail_on(tag, "compile/multi", format!("{e:#}")),
        };
        for (i, input) in case.inputs.iter().enumerate() {
            match multi.run(input) {
                Ok((got, rep)) => {
                    if got != want[i] {
                        return fail_on(
                            tag,
                            "exact/multi",
                            format!("input {i}: {}", first_diff(&got, &want[i])),
                        );
                    }
                    // Every multi-target run prices the overlapped
                    // schedule; it can never exceed the serial total.
                    if rep.overlapped_cycles == 0 || rep.overlapped_cycles > rep.cycles {
                        return fail_on(
                            tag,
                            "async/overlap-le-serial",
                            format!(
                                "input {i}: overlapped {} vs serial {}",
                                rep.overlapped_cycles, rep.cycles
                            ),
                        );
                    }
                    if i == 0 {
                        if let Some(v) = check_report_counters(tag, &rep, &multi.program) {
                            return v;
                        }
                    }
                }
                Err(e) => return fail_on(tag, "exact/multi", format!("input {i}: run: {e:#}")),
            }
        }
        match multi.run_batch(&refs) {
            Ok(batch) => {
                if batch.pipelined_cycles > batch.serial_cycles {
                    return fail_on(
                        tag,
                        "batch/pipelined-le-serial",
                        format!(
                            "multi: pipelined {} > serial {}",
                            batch.pipelined_cycles, batch.serial_cycles
                        ),
                    );
                }
            }
            Err(e) => return fail_on(tag, "batch/exact", format!("multi run_batch: {e:#}")),
        }
    }

    // Axis: run_batch — outputs identical to per-input runs, serial
    // cycles are the sum, pipelined never exceeds serial.
    match dep.run_batch(&sim, &refs) {
        Ok(batch) => {
            for (i, w) in want.iter().enumerate() {
                if &batch.outputs[i] != w {
                    return fail_on(
                        "gemmini",
                        "batch/exact",
                        format!("inference {i}: {}", first_diff(&batch.outputs[i], w)),
                    );
                }
            }
            let sum: u64 = batch.reports.iter().map(|r| r.cycles).sum();
            if batch.serial_cycles != sum {
                return fail_on(
                    "gemmini",
                    "batch/serial-sum",
                    format!("serial_cycles {} != per-inference sum {sum}", batch.serial_cycles),
                );
            }
            if batch.pipelined_cycles > batch.serial_cycles {
                return fail_on(
                    "gemmini",
                    "batch/pipelined-le-serial",
                    format!(
                        "pipelined {} > serial {}",
                        batch.pipelined_cycles, batch.serial_cycles
                    ),
                );
            }
        }
        Err(e) => return fail_on("gemmini", "batch/exact", format!("run_batch: {e:#}")),
    }

    Verdict::Pass
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fuzz::gen::{gen_case, GenOptions};

    #[test]
    fn small_cases_pass_every_axis() {
        // A handful of real end-to-end cases (kept small: each one runs
        // six compiles and a dozen simulations).
        let opts = GenOptions { max_layers: 2, max_dim: 16, max_batch: 2, max_inputs: 2 };
        for seed in [11u64, 12, 13] {
            let case = gen_case(seed, &opts);
            let v = check_case(&case);
            assert!(v.passed(), "seed {seed} failed: {v:?}");
        }
    }

    #[test]
    fn verdict_is_deterministic() {
        let opts = GenOptions { max_layers: 2, max_dim: 12, max_batch: 2, max_inputs: 1 };
        let case = gen_case(99, &opts);
        assert_eq!(check_case(&case), check_case(&case));
    }

    #[test]
    fn pairings_cover_the_cross_family_case() {
        let tags: Vec<&str> =
            multi_target_pairings().unwrap().into_iter().map(|(t, _)| t).collect();
        assert!(tags.contains(&"gemmini+bigarray-os"));
        assert!(tags.contains(&"gemmini+vector"), "cross-family pairing must be fuzzed");
    }
}
