//! Replayable reproducer files (`.repro`).
//!
//! A reproducer archives one [`FuzzCase`] — the minimized model plus the
//! exact inputs — so a fuzz finding survives as a permanent regression
//! test. Format (all little-endian):
//!
//! ```text
//! magic   b"FZRP"        4 bytes
//! version u8 = 2
//! seed    u64            (the originating case seed, for provenance)
//! model_len u32, model   (a `.qmodel` blob, see crate::relay::import)
//! n_inputs  u32
//! per input: len u32, data i8[len]
//! backend_len u32, backend utf-8   (which backend/pairing failed;
//!                                   empty for representative seeds)
//! ```
//!
//! Version 1 files (no trailing backend field) still parse — the
//! backend reads back empty. Writers always emit version 2.
//!
//! The embedded model goes through [`parse_qmodel`]'s full validation on
//! load, and every input length is checked against `batch * in_dim`, so
//! a corrupt corpus entry is a load error, never a confusing mismatch.
//!
//! The committed corpus lives in `rust/tests/corpus/` (one file per
//! reproducer, named `seed-<hex>.repro`) and is replayed against every
//! oracle axis — on every registered backend — by `tests/fuzz_corpus.rs`
//! on `cargo test`.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::relay::import::{parse_qmodel, write_qmodel};

use super::gen::FuzzCase;

const MAGIC: &[u8; 4] = b"FZRP";
const VERSION: u8 = 2;

/// Serialize a case to reproducer bytes with an empty backend field
/// (representative seeds that pass every axis).
pub fn write_repro(case: &FuzzCase) -> Vec<u8> {
    write_repro_tagged(case, "")
}

/// Serialize a case to reproducer bytes, recording which backend (or
/// multi-target pairing) the finding failed on.
pub fn write_repro_tagged(case: &FuzzCase, backend: &str) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&case.seed.to_le_bytes());
    let model = write_qmodel(&case.model);
    out.extend_from_slice(&(model.len() as u32).to_le_bytes());
    out.extend_from_slice(&model);
    out.extend_from_slice(&(case.inputs.len() as u32).to_le_bytes());
    for x in &case.inputs {
        out.extend_from_slice(&(x.len() as u32).to_le_bytes());
        out.extend(x.iter().map(|&v| v as u8));
    }
    out.extend_from_slice(&(backend.len() as u32).to_le_bytes());
    out.extend_from_slice(backend.as_bytes());
    out
}

/// Parse reproducer bytes back into a case (validating the embedded
/// model and every input length), discarding the backend field.
pub fn parse_repro(buf: &[u8]) -> Result<FuzzCase> {
    Ok(parse_repro_tagged(buf)?.0)
}

/// Parse reproducer bytes into the case plus the recorded failed
/// backend (empty for version-1 files and representative seeds).
pub fn parse_repro_tagged(buf: &[u8]) -> Result<(FuzzCase, String)> {
    fn take<'a>(buf: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
        ensure!(*pos + n <= buf.len(), "truncated reproducer at byte {}", *pos);
        let s = &buf[*pos..*pos + n];
        *pos += n;
        Ok(s)
    }
    let mut pos = 0usize;
    if take(buf, &mut pos, 4)? != MAGIC {
        bail!("bad reproducer magic");
    }
    let version = take(buf, &mut pos, 1)?[0];
    ensure!(
        version == 1 || version == VERSION,
        "unsupported reproducer version {version}"
    );
    let seed = u64::from_le_bytes(take(buf, &mut pos, 8)?.try_into().unwrap());
    let model_len = u32::from_le_bytes(take(buf, &mut pos, 4)?.try_into().unwrap()) as usize;
    let model = parse_qmodel(take(buf, &mut pos, model_len)?).context("embedded model")?;
    let n_inputs = u32::from_le_bytes(take(buf, &mut pos, 4)?.try_into().unwrap()) as usize;
    ensure!((1..=1024).contains(&n_inputs), "implausible input count {n_inputs}");
    let elems = model.batch * model.layers[0].in_dim;
    let mut inputs = Vec::with_capacity(n_inputs);
    for i in 0..n_inputs {
        let len = u32::from_le_bytes(take(buf, &mut pos, 4)?.try_into().unwrap()) as usize;
        ensure!(
            len == elems,
            "input {i} has {len} elems, model wants {elems} (batch * in_dim)"
        );
        inputs.push(take(buf, &mut pos, len)?.iter().map(|&b| b as i8).collect());
    }
    let backend = if version >= 2 {
        let len = u32::from_le_bytes(take(buf, &mut pos, 4)?.try_into().unwrap()) as usize;
        ensure!(len <= 256, "implausible backend-field length {len}");
        String::from_utf8(take(buf, &mut pos, len)?.to_vec())
            .context("backend field is not utf-8")?
    } else {
        String::new()
    };
    ensure!(pos == buf.len(), "trailing bytes in reproducer");
    Ok((FuzzCase { seed, model, inputs }, backend))
}

/// The canonical file name for a reproducer: `seed-<hex>.repro`.
pub fn repro_file_name(case: &FuzzCase) -> String {
    format!("seed-{:016x}.repro", case.seed)
}

/// Load a reproducer file (discarding the backend field).
pub fn load_repro(path: &Path) -> Result<FuzzCase> {
    Ok(load_repro_tagged(path)?.0)
}

/// Load a reproducer file plus its recorded failed backend.
pub fn load_repro_tagged(path: &Path) -> Result<(FuzzCase, String)> {
    let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_repro_tagged(&buf).with_context(|| format!("parsing {}", path.display()))
}

/// Write a reproducer into `dir` (created if needed) under its canonical
/// name; returns the path written.
pub fn save_repro(case: &FuzzCase, dir: &Path) -> Result<std::path::PathBuf> {
    save_repro_tagged(case, "", dir)
}

/// [`save_repro`] recording the failed backend in the provenance field.
pub fn save_repro_tagged(
    case: &FuzzCase,
    backend: &str,
    dir: &Path,
) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating reproducer dir {}", dir.display()))?;
    let path = dir.join(repro_file_name(case));
    std::fs::write(&path, write_repro_tagged(case, backend))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fuzz::gen::{gen_case, GenOptions};
    use crate::relay::import::write_qmodel;

    #[test]
    fn roundtrip_preserves_everything() {
        let opts = GenOptions::default();
        for seed in [3u64, 77, 123456789] {
            let case = gen_case(seed, &opts);
            let bytes = write_repro_tagged(&case, "gemmini+vector");
            let (back, backend) = parse_repro_tagged(&bytes).unwrap();
            assert_eq!(back.seed, case.seed);
            assert_eq!(write_qmodel(&back.model), write_qmodel(&case.model));
            assert_eq!(back.inputs, case.inputs);
            assert_eq!(backend, "gemmini+vector");
        }
    }

    #[test]
    fn v1_reproducers_still_parse_with_empty_backend() {
        // A version-1 file is a version-2 file minus the version byte
        // bump and the trailing backend field.
        let case = gen_case(17, &GenOptions::default());
        let v2 = write_repro(&case);
        let mut v1 = v2[..v2.len() - 4].to_vec();
        v1[4] = 1;
        let (back, backend) = parse_repro_tagged(&v1).unwrap();
        assert_eq!(back.seed, case.seed);
        assert_eq!(back.inputs, case.inputs);
        assert_eq!(backend, "");
    }

    #[test]
    fn rejects_corrupt_reproducers() {
        let case = gen_case(9, &GenOptions::default());
        let bytes = write_repro(&case);
        assert!(parse_repro(&bytes[..bytes.len() - 1]).is_err(), "truncated");
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(parse_repro(&bad_magic).is_err(), "bad magic");
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(parse_repro(&extra).is_err(), "trailing bytes");
        let mut bad_version = bytes.clone();
        bad_version[4] = 3;
        assert!(parse_repro(&bad_version).is_err(), "future version");
        // Corrupting the batch inside the embedded model breaks the
        // input-length cross-check (or the model parse itself).
        let mut bad_batch = bytes.clone();
        bad_batch[4 + 1 + 8 + 4 + 9] = 200; // qmodel batch field, low byte
        assert!(parse_repro(&bad_batch).is_err(), "input/batch mismatch");
    }

    #[test]
    fn save_and_load_via_canonical_name() {
        let case = gen_case(21, &GenOptions::default());
        let dir = std::env::temp_dir()
            .join(format!("tvm-accel-fuzz-corpus-{}", std::process::id()));
        let path = save_repro_tagged(&case, "vector", &dir).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap().starts_with("seed-"));
        let (back, backend) = load_repro_tagged(&path).unwrap();
        assert_eq!(back.seed, case.seed);
        assert_eq!(backend, "vector");
        std::fs::remove_dir_all(&dir).ok();
    }
}
