//! A tiny CLI argument parser (stand-in for `clap`, unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    /// `value_opts` lists option names that consume a following value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, value_opts: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&body) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{body} expects a value"))?;
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process's own arguments.
    pub fn from_env(value_opts: &[&str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), value_opts)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("option --{name} expects an integer, got '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed_args() {
        let a = Args::parse(
            argv(&["compile", "--dim", "16", "--verbose", "--out=prog.bin", "model.json"]),
            &["dim", "out"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["compile", "model.json"]);
        assert_eq!(a.opt("dim"), Some("16"));
        assert_eq!(a.opt("out"), Some("prog.bin"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(argv(&["--dim"]), &["dim"]).is_err());
    }

    #[test]
    fn opt_usize_parses() {
        let a = Args::parse(argv(&["--n", "42"]), &["n"]).unwrap();
        assert_eq!(a.opt_usize("n", 7).unwrap(), 42);
        assert_eq!(a.opt_usize("m", 7).unwrap(), 7);
        let bad = Args::parse(argv(&["--n", "xyz"]), &["n"]).unwrap();
        assert!(bad.opt_usize("n", 0).is_err());
    }
}
