//! A YAML-subset parser sufficient for CoSA-style architecture and
//! constraint configuration files (stand-in for `serde_yaml`, unavailable
//! offline — see DESIGN.md).
//!
//! Supported subset:
//! * block mappings (`key: value`, nesting by indentation),
//! * block sequences (`- item`, including `- key: value` item mappings),
//! * inline (flow) sequences `[a, b, c]`,
//! * scalars: integers, floats, booleans, strings (bare or quoted),
//! * `#` comments and blank lines.
//!
//! Anchors, aliases, multi-document streams, flow mappings and block scalars
//! are intentionally unsupported; config files in `configs/` stay within the
//! subset.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed YAML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Yaml {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Seq(Vec<Yaml>),
    /// Ordered map (BTreeMap keeps deterministic iteration for tests).
    Map(BTreeMap<String, Yaml>),
}

impl fmt::Display for Yaml {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Yaml::Null => write!(f, "null"),
            Yaml::Bool(b) => write!(f, "{b}"),
            Yaml::Int(i) => write!(f, "{i}"),
            Yaml::Float(x) => write!(f, "{x}"),
            Yaml::Str(s) => write!(f, "{s}"),
            Yaml::Seq(items) => {
                write!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{it}")?;
                }
                write!(f, "]")
            }
            Yaml::Map(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{k}: {v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

impl Yaml {
    pub fn as_map(&self) -> Result<&BTreeMap<String, Yaml>> {
        match self {
            Yaml::Map(m) => Ok(m),
            other => Err(anyhow!("expected mapping, got {other}")),
        }
    }

    pub fn as_seq(&self) -> Result<&[Yaml]> {
        match self {
            Yaml::Seq(s) => Ok(s),
            other => Err(anyhow!("expected sequence, got {other}")),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Yaml::Int(i) => Ok(*i),
            other => Err(anyhow!("expected integer, got {other}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_i64()?;
        usize::try_from(v).map_err(|_| anyhow!("expected non-negative integer, got {v}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Yaml::Float(x) => Ok(*x),
            Yaml::Int(i) => Ok(*i as f64),
            other => Err(anyhow!("expected number, got {other}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Yaml::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected boolean, got {other}")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Yaml::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other}")),
        }
    }

    /// Map lookup with a contextual error.
    pub fn get(&self, key: &str) -> Result<&Yaml> {
        self.as_map()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// Map lookup returning `None` when the key is absent.
    pub fn get_opt(&self, key: &str) -> Option<&Yaml> {
        match self {
            Yaml::Map(m) => m.get(key),
            _ => None,
        }
    }
}

/// One meaningful line after comment/blank stripping.
#[derive(Debug)]
struct Line {
    indent: usize,
    text: String,
    lineno: usize,
}

fn strip_comment(s: &str) -> &str {
    // A '#' starts a comment unless inside quotes.
    let mut in_s = false;
    let mut in_d = false;
    for (i, ch) in s.char_indices() {
        match ch {
            '\'' if !in_d => in_s = !in_s,
            '"' if !in_s => in_d = !in_d,
            '#' if !in_s && !in_d => {
                // Require preceding whitespace or start-of-line per YAML.
                if i == 0 || s.as_bytes()[i - 1].is_ascii_whitespace() {
                    return &s[..i];
                }
            }
            _ => {}
        }
    }
    s
}

fn lex(src: &str) -> Result<Vec<Line>> {
    let mut out = Vec::new();
    for (i, raw) in src.lines().enumerate() {
        if raw.contains('\t') {
            bail!("line {}: tabs are not allowed in YAML indentation", i + 1);
        }
        let no_comment = strip_comment(raw);
        let trimmed = no_comment.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        let indent = trimmed.len() - trimmed.trim_start().len();
        out.push(Line { indent, text: trimmed.trim_start().to_string(), lineno: i + 1 });
    }
    Ok(out)
}

/// Parse a scalar token into a typed value.
fn parse_scalar(tok: &str) -> Yaml {
    let t = tok.trim();
    if t.is_empty() || t == "~" || t == "null" {
        return Yaml::Null;
    }
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Yaml::Str(t[1..t.len() - 1].to_string());
    }
    match t {
        "true" | "True" => return Yaml::Bool(true),
        "false" | "False" => return Yaml::Bool(false),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Yaml::Int(i);
    }
    if let Ok(x) = t.parse::<f64>() {
        return Yaml::Float(x);
    }
    if t.starts_with('[') && t.ends_with(']') {
        let inner = &t[1..t.len() - 1];
        if inner.trim().is_empty() {
            return Yaml::Seq(Vec::new());
        }
        let items = split_flow(inner).into_iter().map(|s| parse_scalar(&s)).collect();
        return Yaml::Seq(items);
    }
    Yaml::Str(t.to_string())
}

/// Split a flow-sequence body on commas, honoring nested brackets/quotes.
fn split_flow(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_s = false;
    let mut in_d = false;
    let mut cur = String::new();
    for ch in s.chars() {
        match ch {
            '\'' if !in_d => in_s = !in_s,
            '"' if !in_s => in_d = !in_d,
            '[' if !in_s && !in_d => depth += 1,
            ']' if !in_s && !in_d => depth = depth.saturating_sub(1),
            ',' if depth == 0 && !in_s && !in_d => {
                parts.push(std::mem::take(&mut cur));
                continue;
            }
            _ => {}
        }
        cur.push(ch);
    }
    parts.push(cur);
    parts
}

/// Split `key: value` at the first top-level colon. Returns `None` when the
/// line is not a mapping entry.
fn split_key(line: &str) -> Option<(&str, &str)> {
    let mut in_s = false;
    let mut in_d = false;
    let bytes = line.as_bytes();
    for (i, ch) in line.char_indices() {
        match ch {
            '\'' if !in_d => in_s = !in_s,
            '"' if !in_s => in_d = !in_d,
            ':' if !in_s && !in_d => {
                let after_ok = i + 1 >= bytes.len() || bytes[i + 1].is_ascii_whitespace();
                if after_ok {
                    return Some((line[..i].trim(), line[i + 1..].trim()));
                }
            }
            _ => {}
        }
    }
    None
}

struct Parser<'a> {
    lines: &'a [Line],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<&'a Line> {
        self.lines.get(self.pos)
    }

    fn parse_block(&mut self, indent: usize) -> Result<Yaml> {
        let first = match self.peek() {
            Some(l) if l.indent >= indent => l,
            _ => return Ok(Yaml::Null),
        };
        if first.text.starts_with("- ") || first.text == "-" {
            self.parse_seq(first.indent)
        } else {
            self.parse_map(first.indent)
        }
    }

    fn parse_map(&mut self, indent: usize) -> Result<Yaml> {
        let mut map = BTreeMap::new();
        while let Some(line) = self.peek() {
            if line.indent < indent {
                break;
            }
            if line.indent > indent {
                bail!("line {}: unexpected indentation", line.lineno);
            }
            let (key, rest) = split_key(&line.text).ok_or_else(|| {
                anyhow!("line {}: expected 'key: value', got '{}'", line.lineno, line.text)
            })?;
            self.pos += 1;
            let value = if rest.is_empty() {
                // Nested block (map or sequence) or null.
                match self.peek() {
                    Some(next) if next.indent > indent => self.parse_block(next.indent)?,
                    // A sequence may be written at the same indent as its key.
                    Some(next)
                        if next.indent == indent
                            && (next.text.starts_with("- ") || next.text == "-") =>
                    {
                        self.parse_seq(indent)?
                    }
                    _ => Yaml::Null,
                }
            } else {
                parse_scalar(rest)
            };
            if map.insert(key.to_string(), value).is_some() {
                bail!("line {}: duplicate key '{key}'", line.lineno);
            }
        }
        Ok(Yaml::Map(map))
    }

    fn parse_seq(&mut self, indent: usize) -> Result<Yaml> {
        let mut items = Vec::new();
        while let Some(line) = self.peek() {
            if line.indent != indent || !(line.text.starts_with("- ") || line.text == "-") {
                if line.indent >= indent && !line.text.starts_with('-') {
                    break;
                }
                if line.indent < indent {
                    break;
                }
                bail!("line {}: malformed sequence item", line.lineno);
            }
            let body = line.text[1..].trim().to_string();
            let lineno = line.lineno;
            self.pos += 1;
            if body.is_empty() {
                // "-" alone: nested block item.
                let item = match self.peek() {
                    Some(next) if next.indent > indent => self.parse_block(next.indent)?,
                    _ => Yaml::Null,
                };
                items.push(item);
            } else if let Some((key, rest)) = split_key(&body) {
                // "- key: value" starts an item mapping whose further keys
                // sit at indent + 2.
                let mut map = BTreeMap::new();
                let value = if rest.is_empty() {
                    match self.peek() {
                        Some(next) if next.indent > indent + 2 => {
                            self.parse_block(next.indent)?
                        }
                        _ => Yaml::Null,
                    }
                } else {
                    parse_scalar(rest)
                };
                map.insert(key.to_string(), value);
                while let Some(next) = self.peek() {
                    if next.indent != indent + 2 {
                        break;
                    }
                    let (k, r) = split_key(&next.text).ok_or_else(|| {
                        anyhow!("line {}: expected 'key: value' in item map", next.lineno)
                    })?;
                    self.pos += 1;
                    let v = if r.is_empty() {
                        match self.peek() {
                            Some(n2) if n2.indent > indent + 2 => self.parse_block(n2.indent)?,
                            _ => Yaml::Null,
                        }
                    } else {
                        parse_scalar(r)
                    };
                    if map.insert(k.to_string(), v).is_some() {
                        bail!("line {}: duplicate key '{k}'", lineno);
                    }
                }
                items.push(Yaml::Map(map));
            } else {
                items.push(parse_scalar(&body));
            }
        }
        Ok(Yaml::Seq(items))
    }
}

/// Parse a YAML document from a string.
pub fn parse(src: &str) -> Result<Yaml> {
    let lines = lex(src)?;
    if lines.is_empty() {
        return Ok(Yaml::Null);
    }
    let mut p = Parser { lines: &lines, pos: 0 };
    let v = p.parse_block(0)?;
    if let Some(left) = p.peek() {
        bail!("line {}: trailing content '{}'", left.lineno, left.text);
    }
    Ok(v)
}

/// Parse a YAML document from a file.
pub fn parse_file(path: &std::path::Path) -> Result<Yaml> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&src).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("a: 1").unwrap().get("a").unwrap(), &Yaml::Int(1));
        assert_eq!(parse("a: 1.5").unwrap().get("a").unwrap(), &Yaml::Float(1.5));
        assert_eq!(parse("a: true").unwrap().get("a").unwrap(), &Yaml::Bool(true));
        assert_eq!(
            parse("a: hello").unwrap().get("a").unwrap(),
            &Yaml::Str("hello".into())
        );
        assert_eq!(
            parse("a: \"quoted: str\"").unwrap().get("a").unwrap(),
            &Yaml::Str("quoted: str".into())
        );
        assert_eq!(parse("a: ~").unwrap().get("a").unwrap(), &Yaml::Null);
    }

    #[test]
    fn nested_maps() {
        let doc = parse(
            "arch:\n  pe_array:\n    dim: 16\n    dataflow: WS\n  memory:\n    size: 262144\n",
        )
        .unwrap();
        let dim = doc.get("arch").unwrap().get("pe_array").unwrap().get("dim").unwrap();
        assert_eq!(dim, &Yaml::Int(16));
        let size = doc.get("arch").unwrap().get("memory").unwrap().get("size").unwrap();
        assert_eq!(size, &Yaml::Int(262144));
    }

    #[test]
    fn block_sequences() {
        let doc = parse("dims:\n  - N\n  - C\n  - K\n").unwrap();
        let seq = doc.get("dims").unwrap().as_seq().unwrap();
        assert_eq!(seq.len(), 3);
        assert_eq!(seq[0], Yaml::Str("N".into()));
    }

    #[test]
    fn sequence_of_maps() {
        let src = "levels:\n  - name: Scratchpad\n    size: 262144\n  - name: Accumulator\n    size: 65536\n";
        let doc = parse(src).unwrap();
        let levels = doc.get("levels").unwrap().as_seq().unwrap();
        assert_eq!(levels.len(), 2);
        assert_eq!(
            levels[0].get("name").unwrap(),
            &Yaml::Str("Scratchpad".into())
        );
        assert_eq!(levels[1].get("size").unwrap(), &Yaml::Int(65536));
    }

    #[test]
    fn flow_sequences() {
        let doc = parse("shares: [0.25, 0.25, 0.5]\nnames: [in, w, out]\n").unwrap();
        let s = doc.get("shares").unwrap().as_seq().unwrap();
        assert_eq!(s[2], Yaml::Float(0.5));
        let n = doc.get("names").unwrap().as_seq().unwrap();
        assert_eq!(n[1], Yaml::Str("w".into()));
    }

    #[test]
    fn comments_and_blanks() {
        let src = "# top comment\na: 1  # trailing\n\nb: 2\n";
        let doc = parse(src).unwrap();
        assert_eq!(doc.get("a").unwrap(), &Yaml::Int(1));
        assert_eq!(doc.get("b").unwrap(), &Yaml::Int(2));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a: 1\na: 2\n").is_err());
    }

    #[test]
    fn tabs_rejected() {
        assert!(parse("a:\n\tb: 1\n").is_err());
    }

    #[test]
    fn nested_seq_under_item_map() {
        let src = "constraints:\n  - level: PE\n    dims:\n      - C\n      - K\n";
        let doc = parse(src).unwrap();
        let c = &doc.get("constraints").unwrap().as_seq().unwrap()[0];
        let dims = c.get("dims").unwrap().as_seq().unwrap();
        assert_eq!(dims.len(), 2);
    }

    #[test]
    fn empty_doc_is_null() {
        assert_eq!(parse("").unwrap(), Yaml::Null);
        assert_eq!(parse("# only comments\n").unwrap(), Yaml::Null);
    }

    #[test]
    fn type_errors_are_reported() {
        let doc = parse("a: 1").unwrap();
        assert!(doc.get("a").unwrap().as_str().is_err());
        assert!(doc.get("missing").is_err());
        assert!(doc.get("a").unwrap().as_map().is_err());
    }
}
