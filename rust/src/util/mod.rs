//! Infrastructure utilities: a YAML-subset parser, a seeded PRNG, a
//! property-testing harness, plain-text table rendering and a tiny CLI
//! argument parser.
//!
//! These exist because the build environment is offline and the crate set is
//! limited to `xla` + `anyhow` (see DESIGN.md §Offline-environment notes);
//! they replace serde_yaml / proptest / clap / criterion respectively.

pub mod cli;
pub mod prng;
pub mod prop;
pub mod table;
pub mod yaml;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// log2 of a power-of-two usize; panics otherwise (used for address math).
#[inline]
pub fn log2_exact(v: usize) -> u32 {
    assert!(v.is_power_of_two(), "log2_exact({v}): not a power of two");
    v.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 16), 0);
        assert_eq!(round_up(1, 16), 16);
        assert_eq!(round_up(16, 16), 16);
        assert_eq!(round_up(17, 16), 32);
    }

    #[test]
    fn log2_exact_basic() {
        assert_eq!(log2_exact(1), 0);
        assert_eq!(log2_exact(16), 4);
        assert_eq!(log2_exact(1 << 20), 20);
    }

    #[test]
    #[should_panic]
    fn log2_exact_rejects_non_pow2() {
        log2_exact(12);
    }
}
