//! Plain-text table rendering for benchmark and report output, matching the
//! row/column layout of the paper's tables.

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Table { title: title.into(), header: Vec::new(), rows: Vec::new() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cols: Vec<String>) -> &mut Self {
        assert_eq!(
            cols.len(),
            self.header.len(),
            "row width must match header width"
        );
        self.rows.push(cols);
        self
    }

    /// Render with column alignment; numeric-looking cells right-aligned.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    let numeric = c
                        .chars()
                        .all(|ch| ch.is_ascii_digit() || ",.%x~".contains(ch))
                        && !c.is_empty();
                    if numeric {
                        format!(" {:>width$} ", c, width = widths[i])
                    } else {
                        format!(" {:<width$} ", c, width = widths[i])
                    }
                })
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

/// Format an integer with thousands separators, as the paper prints cycles
/// (e.g. `69,994`).
pub fn commafy(v: u64) -> String {
    let s = v.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commafy_cases() {
        assert_eq!(commafy(0), "0");
        assert_eq!(commafy(999), "999");
        assert_eq!(commafy(1000), "1,000");
        assert_eq!(commafy(69994), "69,994");
        assert_eq!(commafy(21508629), "21,508,629");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Deployment results").header(&["Workload", "Cycles"]);
        t.row(vec!["(64,64,64)".into(), commafy(69994)]);
        t.row(vec!["ToyCar".into(), commafy(50064)]);
        let r = t.render();
        assert!(r.contains("Deployment results"));
        assert!(r.contains("69,994"));
        // All data lines have the same width.
        let lines: Vec<&str> = r.lines().skip(1).collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("t").header(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
