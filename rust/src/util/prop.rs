//! A minimal property-based testing harness (stand-in for `proptest`, which
//! is unavailable offline — see DESIGN.md).
//!
//! Usage (doctest disabled: the sandbox cannot load shared libs for
//! rustdoc binaries):
//! ```text
//! use tvm_accel::util::{prop, prng::Rng};
//! prop::check("addition commutes", 100, |rng: &mut Rng| {
//!     let a = rng.range(0, 1000);
//!     let b = rng.range(0, 1000);
//!     prop::assert_prop(a + b == b + a, format!("a={a} b={b}"))
//! });
//! ```
//!
//! Each case receives a deterministically seeded [`Rng`]; on failure the
//! harness reports the case index and seed so the case can be replayed.

use super::prng::Rng;

/// Result of a single property case: `Ok(())` or a failure description.
pub type CaseResult = Result<(), String>;

/// Assert helper producing a [`CaseResult`].
pub fn assert_prop(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of property `f`, panicking with a replayable
/// seed on the first failure.
pub fn check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng) -> CaseResult) {
    check_seeded(name, 0xC0DE_CAFE, cases, &mut f);
}

/// Like [`check`] but with an explicit base seed (use to replay a failure).
pub fn check_seeded(
    name: &str,
    base_seed: u64,
    cases: u64,
    f: &mut impl FnMut(&mut Rng) -> CaseResult,
) {
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed at case {i} (replay: base_seed={base_seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("trivial", 50, |rng| {
            let v = rng.range(0, 10);
            assert_prop(v <= 10, "bounded")
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_reports() {
        check("must fail", 50, |rng| {
            let v = rng.range(0, 10);
            assert_prop(v < 5, format!("v={v}"))
        });
    }
}
