//! A minimal property-based testing harness (stand-in for `proptest`, which
//! is unavailable offline — see DESIGN.md).
//!
//! Usage (doctest disabled: the sandbox cannot load shared libs for
//! rustdoc binaries):
//! ```text
//! use tvm_accel::util::{prop, prng::Rng};
//! prop::check("addition commutes", 100, |rng: &mut Rng| {
//!     let a = rng.range(0, 1000);
//!     let b = rng.range(0, 1000);
//!     prop::assert_prop(a + b == b + a, format!("a={a} b={b}"))
//! });
//! ```
//!
//! Each case receives a deterministically seeded [`Rng`]; on failure the
//! harness reports the case index and seed so the case can be replayed.

use super::prng::Rng;

/// Result of a single property case: `Ok(())` or a failure description.
pub type CaseResult = Result<(), String>;

/// Assert helper producing a [`CaseResult`].
pub fn assert_prop(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of property `f`, panicking with a replayable
/// seed on the first failure.
pub fn check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng) -> CaseResult) {
    check_seeded(name, 0xC0DE_CAFE, cases, &mut f);
}

/// Like [`check`] but with an explicit base seed (use to replay a failure).
pub fn check_seeded(
    name: &str,
    base_seed: u64,
    cases: u64,
    f: &mut impl FnMut(&mut Rng) -> CaseResult,
) {
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            // base_seed + i regenerates this exact case as case 0 of a
            // one-case replay run: the derivation multiplies the sum, so
            // (base + i + 0) * M == (base + i) * M.
            let replay = base_seed.wrapping_add(i);
            panic!(
                "property '{name}' failed at case {i} (case seed {seed:#018x}): {msg}\n  \
                 replay: check_seeded(\"{name}\", {replay:#x}, 1, &mut f)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("trivial", 50, |rng| {
            let v = rng.range(0, 10);
            assert_prop(v <= 10, "bounded")
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_reports() {
        check("must fail", 50, |rng| {
            let v = rng.range(0, 10);
            assert_prop(v < 5, format!("v={v}"))
        });
    }

    #[test]
    fn reported_replay_seed_reproduces_the_failure() {
        use std::panic::{catch_unwind, AssertUnwindSafe};

        // Fails only when the first draw is exactly 3, so most cases
        // pass and the failure lands at some case i > 0 — the
        // interesting replay situation. P(no 3 in 1000 draws) ≈ 1e-58.
        fn octant_prop(rng: &mut Rng) -> CaseResult {
            let v = rng.below(8);
            assert_prop(v != 3, format!("v={v}"))
        }

        let err = catch_unwind(AssertUnwindSafe(|| {
            let mut f = octant_prop;
            check_seeded("octants", 0xFEED, 1000, &mut f);
        }))
        .expect_err("the property must fail within 1000 cases");
        let msg = err.downcast_ref::<String>().expect("panic carries a String").clone();
        assert!(msg.contains("replay: check_seeded(\"octants\", "), "got: {msg}");

        // Parse the replay base out of the printed snippet and run it:
        // case 0 of the replay must hit the very same failure.
        let tail = msg
            .split("check_seeded(\"octants\", 0x")
            .nth(1)
            .unwrap_or_else(|| panic!("no replay snippet in: {msg}"));
        let hex = tail.split(',').next().unwrap().trim();
        let replay = u64::from_str_radix(hex, 16)
            .unwrap_or_else(|e| panic!("bad replay seed '{hex}': {e}"));

        let replay_err = catch_unwind(AssertUnwindSafe(|| {
            let mut f = octant_prop;
            check_seeded("octants", replay, 1, &mut f);
        }))
        .expect_err("the reported replay seed must reproduce the failure");
        let replay_msg = replay_err.downcast_ref::<String>().unwrap();
        assert!(replay_msg.contains("failed at case 0"), "got: {replay_msg}");
        assert!(replay_msg.contains("v=3"), "same case data expected, got: {replay_msg}");
    }
}
