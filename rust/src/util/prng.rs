//! A small, fast, seeded PRNG (xoshiro256**) for tests, property-based
//! testing and synthetic data generation. Deterministic across platforms.

/// xoshiro256** by Blackman & Vigna — public domain reference algorithm.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into the full state, as recommended
        // by the xoshiro authors.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's nearly-divisionless method would be overkill; modulo bias
        // is irrelevant at our bounds (tests, tiny ranges).
        self.next_u64() % bound
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform i8 over the full range (useful for int8 tensor data).
    pub fn i8(&mut self) -> i8 {
        self.next_u64() as i8
    }

    /// A vector of `n` random i8 values.
    pub fn i8_vec(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.i8()).collect()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(7);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
