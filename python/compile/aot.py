"""AOT export: lower the JAX+Pallas models to HLO *text* and write the
matching `.qmodel` parameter files.

This is the only Python entry point in the build (`make artifacts`); the
Rust binary is self-contained afterwards. HLO text — not a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids that the pinned xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts written to --out-dir (default ../artifacts):
  dense_{64,128,256,512}.hlo.txt + .qmodel   (Table 2 single layers)
  toycar.hlo.txt + toycar.qmodel             (Table 2 full network)
  toycar_ref.hlo.txt                         (oracle variant, no Pallas)

Usage: python -m compile.aot [--out-dir DIR] [--skip-dense]
"""

import argparse
import functools
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import export_model, model


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(fn, example_args, path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def export_mlp(layers, batch, name, out_dir, with_ref_variant=False):
    """Export an MLP's forward pass (Pallas path) + its qmodel.

    Weights/biases are exported as *parameters* (HLO text elides large
    constants); the runtime feeds them from the matching .qmodel in layer
    order: x, then (w[C,K] i8, bias[K] i32) per layer.
    """
    import jax.numpy as jnp

    x_spec = jax.ShapeDtypeStruct((batch, layers[0].in_dim), jnp.int8)
    params, metas = model.layer_params(layers)
    param_specs = [
        (
            jax.ShapeDtypeStruct(w.shape, jnp.int8),
            jax.ShapeDtypeStruct(b.shape, jnp.int32),
        )
        for (w, b) in params
    ]
    fwd = functools.partial(model.mlp_forward_params, metas=metas)
    export(fwd, (x_spec, param_specs), os.path.join(out_dir, f"{name}.hlo.txt"))
    if with_ref_variant:
        def fwd_ref(x, ps):
            h = x
            for (w, b), (scale, act, lo, hi) in zip(ps, metas):
                from .kernels import ref as _ref

                h = _ref.qgemm_ref(h, w, b, scale, act=act, lo=lo, hi=hi)
            return (h,)

        export(fwd_ref, (x_spec, param_specs), os.path.join(out_dir, f"{name}_ref.hlo.txt"))
    scales = model.activation_scales(len(layers))
    export_model.write_qmodel(
        os.path.join(out_dir, f"{name}.qmodel"), layers, batch, scales[0]
    )
    print(f"  wrote {name}.qmodel")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    default_out = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    p.add_argument("--out-dir", default=default_out)
    p.add_argument("--skip-dense", action="store_true", help="toycar only")
    # Back-compat with the Makefile's historical `--out file` form.
    p.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = p.parse_args(argv)

    out_dir = os.path.abspath(args.out_dir if args.out is None else os.path.dirname(args.out))
    os.makedirs(out_dir, exist_ok=True)

    print(f"exporting artifacts to {out_dir}")
    if not args.skip_dense:
        for size in [64, 128, 256, 512]:
            layers = model.dense_model(size)
            export_mlp(layers, batch=size, name=f"dense_{size}", out_dir=out_dir)
    toycar = model.toycar_model()
    export_mlp(toycar, batch=1, name="toycar", out_dir=out_dir, with_ref_variant=True)
    print("done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
