"""Pure-jnp correctness oracle for the quantized GEMM kernel.

This file is the semantic contract shared by all three validation legs:

* the Pallas kernel (``gemm.py``) must match it exactly (pytest),
* the Rust simulator's ``requantize`` mirrors ``requantize_i32`` —
  float32 multiply, round-half-to-even, saturate — bit for bit
  (``rust/src/sim/mod.rs``),
* the AOT-exported HLO golden models are built from the same functions.

All arithmetic is exact: int32 accumulation never overflows for the
supported shapes (|acc| <= 640 * 127 * 127 < 2^31), and the requantize
multiply is a single f32 x f32 product in both implementations.
"""

import jax.numpy as jnp

# Activation codes shared with the model/exporter (mirroring the Rust
# `Activation` enum).
ACT_NONE = 0
ACT_RELU = 1
ACT_CLIP = 2


def requantize_i32(acc, scale, act=ACT_NONE, lo=-128, hi=127):
    """int32 accumulator -> int8, matching the Rust simulator exactly.

    Order of operations (keep in sync with ``sim::requantize``):
    scale in f32 -> round half-to-even -> relu -> saturate to [-128, 127]
    -> optional clip to [lo, hi].
    """
    x = acc.astype(jnp.float32) * jnp.float32(scale)
    x = jnp.round(x)  # round-half-to-even, like f32::round_ties_even
    if act == ACT_RELU:
        x = jnp.maximum(x, 0.0)
    q = jnp.clip(x, -128.0, 127.0).astype(jnp.int32)
    if act == ACT_CLIP:
        q = jnp.clip(q, lo, hi)
    return q.astype(jnp.int8)


def gemm_i8_acc(x, w):
    """int8 x int8 -> int32 GEMM: O[n,k] = sum_c X[n,c] * W[c,k]."""
    return jnp.matmul(
        x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
    )


def qgemm_ref(x, w, bias, scale, act=ACT_NONE, lo=-128, hi=127):
    """Reference quantized dense layer: requant(X @ W + bias).

    x: int8 [N, C]; w: int8 [C, K] (accelerator layout); bias: int32 [K].
    Returns int8 [N, K].
    """
    acc = gemm_i8_acc(x, w) + bias.astype(jnp.int32)[None, :]
    return requantize_i32(acc, scale, act, lo, hi)
