"""Layer-1 Pallas kernel: DIM-blocked quantized GEMM with fused
requantization — the compute hot-spot of the system, written the way the
paper's insight maps onto a TPU-class spatial core.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): Gemmini executes a
dense layer as scratchpad-tile mvins feeding a DIMxDIM systolic array with
int32 accumulation and requantize-on-mvout. On TPU the same structure is:

* ``BlockSpec`` tiles = the scratchpad mvin schedule (HBM -> VMEM),
* the per-block ``dot_general`` with ``preferred_element_type=int32`` =
  the systolic GEMM instruction (MXU contraction),
* the grid's k-dimension with an accumulator block revisited across k =
  the accumulator + COMPUTE_ACCUMULATED loop,
* the epilogue on the last k step (bias + requantize + activation) =
  the configured mvout path,
* double buffering = Pallas' automatic pipelining across grid steps.

The kernel runs with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is asserted against ``ref.py`` and the lowered
HLO is what the Rust runtime loads as the golden model.

VMEM accounting for the default blocks (BM=BN=BK=128, int8 inputs, int32
accumulator): A 16 KiB + B 16 KiB + acc 64 KiB + out 16 KiB = 112 KiB per
pipeline stage; x2 for double buffering = 224 KiB « 16 MiB VMEM. MXU
utilization estimate: 128x128x128 block contraction fully tiles the
128x128 MXU (8 passes of 128x128x16), so the structural utilization bound
is 1.0; see EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default block sizes (TPU-friendly; clamped per call for small layers).
DEF_BM = 128
DEF_BN = 128
DEF_BK = 128


def _qgemm_kernel(x_ref, w_ref, b_ref, s_ref, acc_ref, o_ref, *, nk, act, lo, hi):
    """One (i, j, k) grid step: acc += X_blk @ W_blk, epilogue on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = x_ref[...].astype(jnp.int32)
    b = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...] + b_ref[...].astype(jnp.int32)  # bias row broadcast
        scale = s_ref[0, 0]
        x = acc.astype(jnp.float32) * scale
        x = jnp.round(x)
        if act == ref.ACT_RELU:
            x = jnp.maximum(x, 0.0)
        q = jnp.clip(x, -128.0, 127.0).astype(jnp.int32)
        if act == ref.ACT_CLIP:
            q = jnp.clip(q, lo, hi)
        o_ref[...] = q.astype(jnp.int8)


def _round_up(v, m):
    return (v + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("act", "lo", "hi", "bm", "bn", "bk")
)
def qgemm(
    x,
    w,
    bias,
    scale,
    act=ref.ACT_NONE,
    lo=-128,
    hi=127,
    bm=DEF_BM,
    bn=DEF_BN,
    bk=DEF_BK,
):
    """Quantized dense layer via the Pallas kernel.

    x: int8 [N, C]; w: int8 [C, K]; bias: int32 [K]; scale: f32 scalar.
    Returns int8 [N, K]. Inputs are zero-padded to block multiples (exact
    for GEMM) and the result sliced back.
    """
    n, c = x.shape
    c2, k = w.shape
    assert c == c2, f"reduction mismatch {c} vs {c2}"
    assert bias.shape == (k,)

    bm_ = min(bm, _round_up(n, 8))
    bn_ = min(bn, _round_up(k, 8))
    bk_ = min(bk, _round_up(c, 8))
    np_, cp, kp = _round_up(n, bm_), _round_up(c, bk_), _round_up(k, bn_)

    xp = jnp.zeros((np_, cp), jnp.int8).at[:n, :c].set(x)
    wp = jnp.zeros((cp, kp), jnp.int8).at[:c, :k].set(w)
    bp = jnp.zeros((1, kp), jnp.int32).at[0, :k].set(bias)
    sp = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    grid = (np_ // bm_, kp // bn_, cp // bk_)
    kernel = functools.partial(
        _qgemm_kernel, nk=grid[2], act=act, lo=lo, hi=hi
    )
    acc_shape = jax.ShapeDtypeStruct((np_, kp), jnp.int32)
    out_shape = jax.ShapeDtypeStruct((np_, kp), jnp.int8)
    acc, out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),  # X tile (HBM->VMEM)
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),  # W tile
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),  # bias row
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),  # requant scale
        ],
        out_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),  # int32 accumulator
            pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),  # int8 result
        ],
        out_shape=[acc_shape, out_shape],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, bp, sp)
    del acc
    return out[:n, :k]
