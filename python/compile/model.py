"""Layer-2 JAX model: quantized dense layers and the MLPerf-Tiny ToyCar
autoencoder, with every dense layer computed by the Pallas kernel.

The models here are the golden functional references for the Rust system:
`aot.py` lowers them to HLO text and `export_model.py` writes the same
quantized parameters as `.qmodel` files for the Rust importer. Both sides
share one quantization recipe (symmetric int8, round-half-to-even), so
simulator output and XLA output match element-exactly.

Python runs only at build time (`make artifacts`); nothing here is on the
deployment path.
"""

import numpy as np

from .kernels import gemm, ref

# ToyCar autoencoder (MLPerf Tiny anomaly detection): dense stack
# 640-128-128-128-128-8-128-128-128-128-640, relu on all hidden layers.
TOYCAR_WIDTHS = [640, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640]


class QuantLayer:
    """One quantized dense layer (parameters + metadata)."""

    def __init__(self, w_q, bias_q, requant, out_scale, act, lo=-128, hi=127):
        self.w_q = w_q  # int8 [K, C] (TFLite layout, as imported)
        self.bias_q = bias_q  # int32 [K]
        self.requant = np.float32(requant)
        self.out_scale = np.float32(out_scale)
        self.act = act
        self.lo, self.hi = lo, hi

    @property
    def in_dim(self):
        return self.w_q.shape[1]

    @property
    def out_dim(self):
        return self.w_q.shape[0]


def symmetric_scale(x):
    """Scale so max|x| maps to 127 (mirror of relay::quantize)."""
    m = float(np.max(np.abs(x)))
    return np.float32(1.0 if m == 0.0 else m / 127.0)


def quantize_i8(x, scale):
    """Round-half-to-even int8 quantization (mirror of relay::quantize)."""
    q = np.clip(np.rint(np.asarray(x, np.float32) / np.float32(scale)), -128, 127)
    return q.astype(np.int8)


def quantize_mlp(float_layers, act_scales):
    """Post-training quantization of an MLP.

    float_layers: list of (weight [K,C] f32, bias [K] f32, act_code).
    act_scales: per-boundary activation scales, len = n_layers + 1.
    """
    assert len(act_scales) == len(float_layers) + 1
    out = []
    for i, (w, b, act) in enumerate(float_layers):
        s_in, s_out = np.float32(act_scales[i]), np.float32(act_scales[i + 1])
        s_w = symmetric_scale(w)
        w_q = quantize_i8(w, s_w)
        bias_q = np.rint(np.asarray(b, np.float32) / (s_in * s_w)).astype(np.int32)
        requant = np.float32(np.float32(s_in * s_w) / s_out)
        out.append(QuantLayer(w_q, bias_q, requant, s_out, act))
    return out


def random_mlp(widths, seed, weight_scale=0.25, relu_hidden=True):
    """Deterministic float MLP used for both the .qmodel export and the
    HLO golden model (same seed => identical parameters everywhere)."""
    rng = np.random.RandomState(seed)
    layers = []
    for i, (cin, cout) in enumerate(zip(widths[:-1], widths[1:])):
        w = rng.normal(0.0, weight_scale / np.sqrt(cin), (cout, cin)).astype(np.float32)
        b = rng.normal(0.0, 0.05, (cout,)).astype(np.float32)
        act = ref.ACT_RELU if (relu_hidden and i + 2 < len(widths)) else ref.ACT_NONE
        layers.append((w, b, act))
    return layers


def activation_scales(n_layers, base=0.04):
    """Fixed calibration scales (a real flow would measure these)."""
    return [np.float32(base * (1.0 + 0.25 * i)) for i in range(n_layers + 1)]


def mlp_forward(x_q, layers):
    """Quantized forward pass; every dense layer runs the Pallas kernel.

    x_q: int8 [batch, in_dim]. Returns int8 [batch, out_dim].
    """
    h = x_q
    for l in layers:
        # Kernel consumes accelerator-layout weights [C, K].
        w_ck = np.ascontiguousarray(l.w_q.T)
        h = gemm.qgemm(h, w_ck, l.bias_q, l.requant, act=l.act, lo=l.lo, hi=l.hi)
    return (h,)


def mlp_forward_params(x_q, params, metas):
    """Forward pass with *traced* parameters (used for AOT export).

    Large weight constants do not survive the HLO-text interchange (the
    printer elides them), so the exported computation takes weights and
    biases as arguments: ``params`` is a list of (w_ck int8 [C,K],
    bias int32 [K]) and ``metas`` the static per-layer (requant, act, lo,
    hi) tuples. The Rust runtime feeds the parameters from the .qmodel.
    """
    h = x_q
    for (w_ck, bias), (scale, act, lo, hi) in zip(params, metas):
        h = gemm.qgemm(h, w_ck, bias, scale, act=act, lo=lo, hi=hi)
    return (h,)


def layer_params(layers):
    """(params, metas) split of a quantized MLP for `mlp_forward_params`."""
    params = [
        (np.ascontiguousarray(l.w_q.T), np.asarray(l.bias_q, np.int32)) for l in layers
    ]
    metas = tuple((float(l.requant), l.act, l.lo, l.hi) for l in layers)
    return params, metas


def mlp_forward_ref(x_q, layers):
    """Same forward pass through the pure-jnp oracle (no Pallas)."""
    h = x_q
    for l in layers:
        w_ck = np.ascontiguousarray(l.w_q.T)
        h = ref.qgemm_ref(h, w_ck, l.bias_q, l.requant, act=l.act, lo=l.lo, hi=l.hi)
    return (h,)


def toycar_model(seed=1234):
    """The quantized ToyCar autoencoder."""
    floats = random_mlp(TOYCAR_WIDTHS, seed)
    scales = activation_scales(len(floats))
    return quantize_mlp(floats, scales)


def dense_model(size, seed=100):
    """A single square dense layer (Table 2 single-layer workloads):
    N = batch = size, C = K = size."""
    floats = random_mlp([size, size], seed + size, relu_hidden=False)
    scales = activation_scales(1)
    return quantize_mlp(floats, scales)
