"""Write quantized models as `.qmodel` binaries for the Rust importer.

Format (little-endian; mirror of `rust/src/relay/import.rs`):

    magic   b"QMDL", version u8 = 1
    n_layers u32, batch u32, input_scale f32
    per layer:
      in_dim u32, out_dim u32, requant f32, out_scale f32,
      act u8 (0 none / 1 relu / 2 clip), lo i8, hi i8,
      weights i8[out_dim * in_dim]   (TFLite layout [out, in])
      bias    i32[out_dim]
"""

import struct

import numpy as np


def write_qmodel(path, layers, batch, input_scale):
    """Serialize a list of `model.QuantLayer` to `path`."""
    with open(path, "wb") as f:
        f.write(b"QMDL")
        f.write(struct.pack("<B", 1))
        f.write(struct.pack("<IIf", len(layers), batch, float(input_scale)))
        for l in layers:
            f.write(
                struct.pack(
                    "<IIffBbb",
                    l.in_dim,
                    l.out_dim,
                    float(l.requant),
                    float(l.out_scale),
                    l.act,
                    l.lo,
                    l.hi,
                )
            )
            w = np.ascontiguousarray(l.w_q, dtype=np.int8)
            assert w.shape == (l.out_dim, l.in_dim)
            f.write(w.tobytes())
            f.write(np.ascontiguousarray(l.bias_q, dtype=np.int32).tobytes())
