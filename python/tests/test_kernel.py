"""L1 correctness: the Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes, activations and block sizes; every case must be
element-exact (the kernel and oracle share one integer/f32 semantics).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm, ref

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


def _rand(rng, n, c, k):
    x = rng.integers(-128, 128, (n, c)).astype(np.int8)
    w = rng.integers(-128, 128, (c, k)).astype(np.int8)
    b = rng.integers(-2000, 2000, (k,)).astype(np.int32)
    return x, w, b


def _check(x, w, b, scale, act=ref.ACT_NONE, lo=-128, hi=127, **blocks):
    got = np.asarray(gemm.qgemm(x, w, b, scale, act=act, lo=lo, hi=hi, **blocks))
    want = np.asarray(ref.qgemm_ref(x, w, b, scale, act=act, lo=lo, hi=hi))
    np.testing.assert_array_equal(got, want)


@given(
    n=st.integers(1, 96),
    c=st.integers(1, 96),
    k=st.integers(1, 96),
    act=st.sampled_from([ref.ACT_NONE, ref.ACT_RELU, ref.ACT_CLIP]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_random_shapes(n, c, k, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, n, c, k)
    scale = np.float32(0.5 ** rng.integers(3, 10))
    _check(x, w, b, scale, act=act, lo=-100, hi=100)


@given(
    bm=st.sampled_from([8, 16, 32, 128]),
    bn=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 16, 32, 128]),
)
def test_kernel_block_shapes(bm, bn, bk):
    rng = np.random.default_rng(7)
    x, w, b = _rand(rng, 48, 40, 24)
    _check(x, w, b, np.float32(0.02), act=ref.ACT_RELU, bm=bm, bn=bn, bk=bk)


@pytest.mark.parametrize(
    "n,c,k",
    [(1, 640, 128), (1, 128, 8), (1, 8, 128), (64, 64, 64), (3, 5, 7)],
)
def test_kernel_workload_shapes(n, c, k):
    rng = np.random.default_rng(n * 1000 + c * 10 + k)
    x, w, b = _rand(rng, n, c, k)
    _check(x, w, b, np.float32(0.01), act=ref.ACT_RELU)


def test_saturation_both_rails():
    # Force saturation in both directions: huge accumulators.
    n = c = k = 16
    x = np.full((n, c), 127, np.int8)
    w = np.full((c, k), 127, np.int8)
    b = np.zeros(k, np.int32)
    _check(x, w, b, np.float32(1.0))
    w_neg = np.full((c, k), -128, np.int8)
    _check(x, w_neg, b, np.float32(1.0))


def test_round_half_to_even():
    # acc * 0.5 hits exact .5 values: 1*0.5 = 0.5 -> 0, 3*0.5 = 1.5 -> 2.
    x = np.array([[1, 0], [3, 0]], np.int8)
    w = np.array([[1], [0]], np.int8)
    b = np.zeros(1, np.int32)
    got = np.asarray(gemm.qgemm(x, w, b, np.float32(0.5)))
    np.testing.assert_array_equal(got[:, 0], [0, 2])


def test_clip_activation_bounds():
    rng = np.random.default_rng(11)
    x, w, b = _rand(rng, 8, 8, 8)
    got = np.asarray(
        gemm.qgemm(x, w, b, np.float32(1.0), act=ref.ACT_CLIP, lo=-5, hi=5)
    )
    assert got.min() >= -5 and got.max() <= 5


def test_relu_never_negative():
    rng = np.random.default_rng(12)
    x, w, b = _rand(rng, 16, 32, 16)
    got = np.asarray(gemm.qgemm(x, w, b, np.float32(0.03), act=ref.ACT_RELU))
    assert got.min() >= 0
