"""L2 correctness: model construction, quantization determinism, Pallas vs
oracle forward passes, and the .qmodel serialization format."""

import io
import struct

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import export_model, model
from compile.kernels import ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def test_toycar_topology():
    layers = model.toycar_model()
    assert len(layers) == 10
    dims = [(l.in_dim, l.out_dim) for l in layers]
    assert dims[0] == (640, 128)
    assert dims[4] == (128, 8)
    assert dims[-1] == (128, 640)
    # Hidden layers relu, output layer linear.
    assert all(l.act == ref.ACT_RELU for l in layers[:-1])
    assert layers[-1].act == ref.ACT_NONE


def test_model_generation_deterministic():
    a = model.toycar_model()
    b = model.toycar_model()
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(la.w_q, lb.w_q)
        np.testing.assert_array_equal(la.bias_q, lb.bias_q)
        assert la.requant == lb.requant


def test_pallas_forward_matches_oracle_toycar_slice():
    # Two representative layers of ToyCar (keeps CI fast); full-network
    # equivalence is covered by the Rust golden check.
    layers = model.toycar_model()[:2]
    rng = np.random.default_rng(5)
    x = rng.integers(-128, 128, (1, 640)).astype(np.int8)
    (got,) = model.mlp_forward(x, layers)
    (want,) = model.mlp_forward_ref(x, layers)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(size=st.sampled_from([16, 32, 64]), seed=st.integers(0, 1000))
def test_pallas_forward_matches_oracle_dense(size, seed):
    layers = model.dense_model(size)
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (size, size)).astype(np.int8)
    (got,) = model.mlp_forward(x, layers)
    (want,) = model.mlp_forward_ref(x, layers)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_round_half_even():
    q = model.quantize_i8(np.array([0.5, 1.5, -0.5, 2.5]), 1.0)
    np.testing.assert_array_equal(q, [0, 2, 0, 2])


def test_qmodel_serialization_layout(tmp_path):
    layers = model.dense_model(16)
    path = tmp_path / "m.qmodel"
    export_model.write_qmodel(str(path), layers, batch=16, input_scale=0.04)
    blob = path.read_bytes()
    assert blob[:4] == b"QMDL"
    assert blob[4] == 1
    n_layers, batch, in_scale = struct.unpack_from("<IIf", blob, 5)
    assert n_layers == 1 and batch == 16
    assert abs(in_scale - 0.04) < 1e-7
    in_dim, out_dim, requant, out_scale, act, lo, hi = struct.unpack_from(
        "<IIffBbb", blob, 17
    )
    assert (in_dim, out_dim) == (16, 16)
    assert requant == float(layers[0].requant)
    # Exact total size: header + per-layer header + weights + bias.
    expected = 17 + 19 + 16 * 16 + 16 * 4
    assert len(blob) == expected


def test_activation_scales_monotone():
    s = model.activation_scales(4)
    assert len(s) == 5
    assert all(b > a for a, b in zip(s, s[1:]))
