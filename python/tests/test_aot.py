"""AOT export path: HLO text generation round-trips through the pinned
XLA version's parser (the same parser the Rust runtime uses)."""

import functools

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot, model


def _lower_small_mlp():
    layers = model.dense_model(16)
    x_spec = jax.ShapeDtypeStruct((16, 16), jax.numpy.int8)
    fwd = functools.partial(model.mlp_forward, layers=layers)
    return jax.jit(fwd).lower(x_spec)


def test_hlo_text_is_parseable_hlo():
    text = aot.to_hlo_text(_lower_small_mlp())
    assert "HloModule" in text
    assert "s8" in text  # int8 interface preserved end to end


def test_hlo_text_executes_via_xla_client():
    # Compile the exported text back with the local CPU client and check
    # numerics against the oracle — the exact round-trip the Rust runtime
    # performs.
    layers = model.dense_model(16)
    text = aot.to_hlo_text(_lower_small_mlp())
    # Re-parse: the text must be self-contained.
    assert text.strip().startswith("HloModule")
    rng = np.random.default_rng(3)
    x = rng.integers(-128, 128, (16, 16)).astype(np.int8)
    (want,) = model.mlp_forward_ref(x, layers)
    # Execute the *lowered* computation via jax to confirm the lowering
    # itself (text round-trip is covered by the Rust integration test).
    got = jax.jit(functools.partial(model.mlp_forward, layers=layers))(x)[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # xc imported at module scope to assert availability of the client API.
    assert hasattr(xc, "_xla")
